//! Native backend: a pure-rust, multi-layer, multi-head f32 Transformer-VQ
//! engine implementing the [`crate::runtime::Backend`] contract with zero
//! external dependencies — no XLA, no HLO artifacts, no python. A fresh
//! checkout trains, serves, and benchmarks with `cargo run` alone.
//!
//! * [`layout`] — the positional leaf contract (groups, shapes, dtypes),
//!   generated from a [`ModelConfig`] instead of read from a manifest.
//! * [`kernels`] — cache-blocked matmul kernels + the thread pool; every
//!   matmul call site in the engine routes through it, and every step
//!   entry point parallelizes over batch lanes with bit-identical results
//!   at any thread count (see DESIGN.md §7, "Performance model").
//! * `model` — the flat-f32 forward pass: Theorem 3.7 block recurrence with
//!   the running-mean compressive cache + rolling 2L window, so decode is
//!   O(S + 2L) per token at any position.
//! * `autodiff` — the f64 differentiable twin of the forward + exact
//!   reverse sweep (straight-through quantizer, commit loss, cache-fold
//!   adjoints), finite-difference checked in its tests.
//! * `step` — decode / prefill / train / eval step functions (full-model
//!   Adam backprop + §3.4.1 EMA codebook learning). The prefill entry is
//!   the serving session path: multi-token chunked prompt ingestion with
//!   per-lane lengths, logits only for each lane's last token, inactive
//!   lanes untouched (see DESIGN.md §8).
//!
//! Presets mirror `config.rs` recipes (quickstart, enwik8-tiny, ablations,
//! …) plus a `tput-*` bench grid comparing the VQ linear path against a
//! dense quadratic "Full" baseline, so the paper-table harness runs natively.
//!
//! Runtime knobs live in [`NativeOptions`], resolved once at backend
//! construction and fixed for every executor it loads:
//! * `num_threads` — pool budget (`TVQ_NUM_THREADS` / `--threads`; 0 =
//!   all cores). Bit-identical results at any value.
//! * `simd` — instruction set for the f32 kernels ([`SimdMode`]; AVX2+FMA
//!   auto-detected, `TVQ_SIMD=0` forces the scalar fallback). Bits are
//!   deterministic *per mode*; modes agree to ≤ 1e-5 kernel tolerance.
//! * `batched_decode` — decode/prefill advance all active lanes through
//!   each layer together (one GEMM per projection, weights streamed once
//!   per step; the default) vs. one lane per pool item
//!   (`TVQ_BATCHED_DECODE=0`).
//! * `precision` — weight precision for decode/prefill ([`Precision`];
//!   `TVQ_PRECISION=bf16|int8` / `--precision`). Weights are quantized
//!   once at install time and streamed as bf16 or int8-with-row-scales
//!   while all accumulation stays f32; train/eval always run f32/f64.
//!   Bits are deterministic per (SIMD × precision) pair at any thread
//!   count; reduced modes agree with f32 to pinned tolerances
//!   (`rust/tests/precision_oracle.rs`).
//!
//! [`DecodeSession`] is the allocation-free stateful decode loop on top
//! of the same model code: weights parsed once, state and scratch arenas
//! owned by the session, zero heap allocations per steady-state token.

pub mod kernels;
pub mod layout;
pub mod simd;
pub mod snapshot;

mod autodiff;
mod model;
mod session;
mod step;

pub use layout::Layout;
pub use session::DecodeSession;
pub use simd::{MatRef, Precision, SimdMode};
pub use snapshot::{LaneLayer, LaneSnapshot, SessionSnapshot};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::manifest::{ArtifactSpec, ModelConfig};
use crate::runtime::{validate_inputs, Backend, Executor};
use crate::tensor::HostTensor;

use step::ParsedWeights;

/// Knobs that vary across native presets; everything else is fixed in
/// [`Dims::build`].
struct Dims {
    d_model: usize,
    n_heads: usize,
    d_k: usize,
    d_v: usize,
    n_layers: usize,
    n_code: usize,
    block_len: usize,
    window_len: usize,
    batch_size: usize,
}

impl Dims {
    fn build(self, attn_type: &str, head_type: &str, use_cache: bool) -> ModelConfig {
        ModelConfig {
            vocab_size: 256,
            d_model: self.d_model,
            d_k: self.d_k,
            d_v: self.d_v,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            head_type: head_type.into(),
            attn_type: attn_type.into(),
            n_code: self.n_code,
            block_len: self.block_len,
            reduction: "native".into(),
            use_cache,
            use_kernel: false,
            window_len: self.window_len,
            batch_size: self.batch_size,
            commit_coef: 1e-4,
            ema_rate: 0.99,
            grad_clip: 0.1,
            use_abs_pe: false,
        }
    }
}

/// Model configuration for a named native preset.
///
/// Shapes are scaled ~100x down from the paper's TPU models (this is a CPU
/// testbed); the *structure* — VQ-attention with compressive cache, gated
/// FFN, byte vocab — matches.
pub fn preset_config(name: &str) -> Result<ModelConfig> {
    let cfg = |dims: [usize; 9], attn_type: &str, head_type: &str, use_cache: bool| {
        let [d_model, n_heads, d_k, d_v, n_layers, n_code, block_len, window_len, batch_size] =
            dims;
        Dims {
            d_model,
            n_heads,
            d_k,
            d_v,
            n_layers,
            n_code,
            block_len,
            window_len,
            batch_size,
        }
        .build(attn_type, head_type, use_cache)
    };
    Ok(match name {
        // dims: [d_model, H, d_k, d_v, layers, S, L, W, B]
        "quickstart" => cfg([64, 2, 16, 32, 2, 32, 16, 64, 4], "vq", "shga", true),
        "enwik8-tiny" | "pg19-tiny" | "imagenet64-tiny" => {
            cfg([64, 2, 16, 32, 2, 64, 32, 128, 4], "vq", "shga", true)
        }
        "enwik8-tiny-full" => cfg([64, 2, 16, 32, 2, 64, 32, 128, 4], "full", "shga", true),
        "ablate-S32" => cfg([64, 2, 16, 32, 2, 32, 16, 64, 4], "vq", "shga", true),
        "ablate-S64" | "ablate-cache" => {
            cfg([64, 2, 16, 32, 2, 64, 16, 64, 4], "vq", "shga", true)
        }
        "ablate-S128" => cfg([64, 2, 16, 32, 2, 128, 16, 64, 4], "vq", "shga", true),
        "ablate-nocache" => cfg([64, 2, 16, 32, 2, 64, 16, 64, 4], "vq", "shga", false),
        other => {
            // bench grid: tput-<head>-<variant>-T<len> (grammar shared with
            // paperbench::measure_throughput_grid)
            let Some((head, variant, t)) = crate::paperbench::parse_tput_name(other) else {
                bail!("no native config for preset '{other}'");
            };
            let n_heads = match head {
                "shga" => 1,
                "mqa" => 2,
                "mha" => 4,
                h => bail!("unknown head type '{h}' in '{other}'"),
            };
            let attn = if variant.starts_with("full") { "full" } else { "vq" };
            cfg([32, n_heads, 8, 16, 2, 64, 32, t, 1], attn, head, true)
        }
    })
}

/// 64-bit FNV-1a: stable per-preset init seed.
fn preset_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

struct ArtifactEntry {
    entry: String,
    cfg: ModelConfig,
}

/// Runtime knobs for the native backend, threaded into every executor it
/// loads. Resolved once (env lookups, CPU feature detection) at backend
/// construction — executors never re-probe mid-flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NativeOptions {
    /// Thread budget per step: batch lanes (and, on the dense path, token
    /// blocks / GEMM row bands) run on up to this many threads. `0` means
    /// all cores. Results are bit-identical at any value — this is purely
    /// a throughput knob.
    pub num_threads: usize,
    /// Instruction set for the f32 kernels. Bit-determinism is guaranteed
    /// *within* a mode; scalar and AVX2+FMA agree to kernel tolerance
    /// (≤ 1e-5), not bits.
    pub simd: SimdMode,
    /// Advance all active decode/prefill lanes through each layer
    /// together (one GEMM per projection — weights stream from memory
    /// once per step instead of once per lane). On by default; the
    /// per-lane fallback remains for comparison benches and as an escape
    /// hatch. Within either path, results are bit-deterministic.
    pub batched_decode: bool,
    /// Weight precision for the decode/prefill hot path. Reduced modes
    /// quantize the matmul weights (and, for int8, the codebooks) once at
    /// weight-install time and stream the narrow encodings in-kernel;
    /// accumulation stays f32 everywhere. Train/eval/bench entries ignore
    /// this and always run full f32/f64. Bit-determinism holds per
    /// (SIMD × precision) pair at any thread count.
    pub precision: Precision,
}

impl NativeOptions {
    /// Default options with an explicit thread budget (bench sweeps).
    pub fn with_threads(num_threads: usize) -> Self {
        Self { num_threads, ..Self::default() }
    }
}

impl Default for NativeOptions {
    /// `TVQ_NUM_THREADS` if set and parseable, else 0 (= all cores);
    /// SIMD per `TVQ_SIMD` (unset = auto-detect, `0` = scalar); batched
    /// decode unless `TVQ_BATCHED_DECODE=0`; precision per
    /// `TVQ_PRECISION` (unset = f32).
    fn default() -> Self {
        let num_threads = std::env::var("TVQ_NUM_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let batched_decode = !matches!(
            std::env::var("TVQ_BATCHED_DECODE").ok().as_deref(),
            Some("0") | Some("off") | Some("false")
        );
        Self {
            num_threads,
            simd: SimdMode::from_env(),
            batched_decode,
            precision: Precision::from_env(),
        }
    }
}

/// Pure-rust [`Backend`]: always available, nothing required on disk.
pub struct NativeBackend {
    artifacts: BTreeMap<String, ArtifactEntry>,
    /// Init-state seed per preset (default: FNV of the preset name).
    seeds: BTreeMap<String, u64>,
    options: NativeOptions,
}

/// Trainable presets registered by [`NativeBackend::new`].
pub const PRESETS: &[&str] = &[
    "quickstart",
    "enwik8-tiny",
    "enwik8-tiny-full",
    "pg19-tiny",
    "imagenet64-tiny",
    "ablate-S32",
    "ablate-S64",
    "ablate-S128",
    "ablate-cache",
    "ablate-nocache",
];

impl NativeBackend {
    pub fn new() -> Self {
        let mut b = Self {
            artifacts: BTreeMap::new(),
            seeds: BTreeMap::new(),
            options: NativeOptions::default(),
        };
        for preset in PRESETS {
            let cfg = preset_config(preset).expect("builtin preset");
            b.register(preset, cfg, preset_seed(preset));
        }
        for head in ["shga", "mqa", "mha"] {
            for variant in ["full", "vq-matmul"] {
                for t in [256usize, 512, 1024] {
                    let name = format!("tput-{head}-{variant}-T{t}");
                    let cfg = preset_config(&name).expect("builtin tput preset");
                    b.seeds.insert(name.clone(), preset_seed(&name));
                    b.artifacts
                        .insert(name, ArtifactEntry { entry: "bench".into(), cfg });
                }
            }
        }
        b
    }

    /// Backend with one custom preset (tests / experiments): registers
    /// `<name>.train`, `<name>.eval`, and (for VQ attention)
    /// `<name>.decode`.
    pub fn with_preset(name: &str, cfg: ModelConfig, seed: u64) -> Self {
        let mut b = Self {
            artifacts: BTreeMap::new(),
            seeds: BTreeMap::new(),
            options: NativeOptions::default(),
        };
        b.register(name, cfg, seed);
        b
    }

    /// Pin runtime options (builder style); executors loaded afterwards
    /// inherit them. Used by the bench sweeps to fix the thread count.
    pub fn with_options(mut self, options: NativeOptions) -> Self {
        self.options = options;
        self
    }

    fn register(&mut self, preset: &str, cfg: ModelConfig, seed: u64) {
        self.seeds.insert(preset.to_string(), seed);
        self.artifacts.insert(
            format!("{preset}.train"),
            ArtifactEntry { entry: "train".into(), cfg: cfg.clone() },
        );
        self.artifacts.insert(
            format!("{preset}.eval"),
            ArtifactEntry { entry: "eval".into(), cfg: cfg.clone() },
        );
        if cfg.attn_type != "full" {
            // dense attention has no O(1) per-token recurrence to decode with
            self.artifacts.insert(
                format!("{preset}.prefill"),
                ArtifactEntry { entry: "prefill".into(), cfg: cfg.clone() },
            );
            self.artifacts.insert(
                format!("{preset}.decode"),
                ArtifactEntry { entry: "decode".into(), cfg },
            );
        }
    }

    fn build_spec(&self, name: &str) -> Result<ArtifactSpec> {
        let Some(a) = self.artifacts.get(name) else {
            let known: Vec<_> = self.artifacts.keys().take(20).collect();
            bail!("native backend has no artifact '{name}' (known: {known:?} ...)");
        };
        let layout = Layout::new(a.cfg.clone());
        Ok(match a.entry.as_str() {
            "decode" => layout.decode_spec(name),
            "prefill" => layout.prefill_spec(name),
            "train" => layout.train_spec(name),
            entry => layout.eval_spec(name, entry),
        })
    }

    /// The options every executor loaded from this backend inherits.
    pub(crate) fn options(&self) -> NativeOptions {
        self.options
    }

    /// Config used to initialize `preset` (either a trainable preset name
    /// or a full bench-artifact name).
    fn init_config(&self, preset: &str) -> Result<(&ModelConfig, u64)> {
        let entry = self
            .artifacts
            .get(&format!("{preset}.train"))
            .or_else(|| self.artifacts.get(preset));
        match entry {
            Some(a) => Ok((&a.cfg, *self.seeds.get(preset).unwrap_or(&0))),
            None => bail!("native backend has no preset '{preset}'"),
        }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".into()
    }

    fn load(&self, name: &str) -> Result<Box<dyn Executor>> {
        let spec = self.build_spec(name)?;
        let layout = Layout::new(spec.config.clone());
        Ok(Box::new(NativeExecutor {
            name: name.to_string(),
            spec,
            layout,
            cache: Mutex::new(None),
            scratch: Mutex::new(step::DecodeArena::default()),
            options: self.options,
        }))
    }

    fn spec(&self, name: &str) -> Result<ArtifactSpec> {
        self.build_spec(name)
    }

    fn init_state(&self, preset: &str) -> Result<Vec<(String, HostTensor)>> {
        let (cfg, seed) = self.init_config(preset)?;
        Ok(Layout::new(cfg.clone()).init_state(seed))
    }

    fn artifact_names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }
}

/// A parsed weight set pinned to the identity of the tensors it came from.
/// The pins hold the `Arc` buffers alive, so the recorded addresses cannot
/// be recycled by another allocation while this entry exists.
struct WeightCacheEntry {
    key: Vec<usize>,
    _pins: Vec<HostTensor>,
    weights: Arc<ParsedWeights>,
}

/// One native step function (decode / train / eval / bench).
///
/// Executors are pure — all state flows through the inputs/outputs — but
/// purity does not require re-parsing the (unchanged) weight bytes every
/// call: `cache` memoizes the parsed params/codebooks keyed by the identity
/// of the incoming weight buffers (see `Bytes::identity`). Decode and eval
/// hit it for free since the bundle re-presents the same buffers each step;
/// the train step re-seeds it with the weights it just produced, so a
/// training loop also parses nothing after the first step.
pub struct NativeExecutor {
    name: String,
    spec: ArtifactSpec,
    layout: Layout,
    cache: Mutex<Option<WeightCacheEntry>>,
    /// Reusable decode scratch (batched arena and/or per-lane arenas):
    /// taken out for the duration of a step and parked back after, so
    /// steady-state serving through the executor surface stops
    /// re-allocating activation matrices every call (a rare concurrent
    /// second caller just builds fresh arenas rather than blocking).
    scratch: Mutex<step::DecodeArena>,
    /// Runtime knobs fixed at executor init (thread budget, SIMD mode,
    /// decode batching). Thread count and batching are throughput knobs;
    /// the SIMD mode additionally picks which deterministic bit-stream
    /// the executor produces (see [`SimdMode`]).
    options: NativeOptions,
}

impl NativeExecutor {
    fn weights_for(&self, tensors: &[HostTensor], n_weights: usize) -> Result<Arc<ParsedWeights>> {
        let key: Vec<usize> = tensors[..n_weights].iter().map(|t| t.data.identity()).collect();
        // one guard across check-parse-insert: no double lock, and a
        // concurrently seeded entry cannot be clobbered by a stale parse
        let mut guard = self.cache.lock().unwrap();
        if let Some(entry) = guard.as_ref() {
            if entry.key == key {
                return Ok(Arc::clone(&entry.weights));
            }
        }
        // Reduced precision applies only to the serving hot path; train,
        // eval, and bench entries always parse full-precision weights.
        let precision = if matches!(self.spec.entry.as_str(), "decode" | "prefill") {
            self.options.precision
        } else {
            Precision::F32
        };
        let weights = Arc::new(step::parse_weights(&self.layout, tensors, precision)?);
        *guard = Some(WeightCacheEntry {
            key,
            _pins: tensors[..n_weights].to_vec(),
            weights: Arc::clone(&weights),
        });
        Ok(weights)
    }

    fn seed_cache(&self, tensors: &[HostTensor], n_weights: usize, weights: ParsedWeights) {
        let key: Vec<usize> = tensors[..n_weights].iter().map(|t| t.data.identity()).collect();
        *self.cache.lock().unwrap() = Some(WeightCacheEntry {
            key,
            _pins: tensors[..n_weights].to_vec(),
            weights: Arc::new(weights),
        });
    }
}

impl Executor for NativeExecutor {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        validate_inputs(&self.name, &self.spec, inputs)?;
        let n_weights = step::weight_tensor_count(&self.layout);
        let weights = self.weights_for(inputs, n_weights)?;
        // take the parked scratch arenas for this step, park them back
        // after — decode/prefill reuse them instead of re-allocating per
        // call (on error the taken arenas are still returned first)
        let mut arena = std::mem::take(&mut *self.scratch.lock().unwrap());
        let result = step::run_entry(
            &self.spec.entry,
            &self.layout,
            &weights,
            inputs,
            &self.options,
            &mut arena,
        );
        *self.scratch.lock().unwrap() = arena;
        let (outputs, new_weights) = result?;
        debug_assert_eq!(outputs.len(), self.spec.outputs.len());
        if let Some(nw) = new_weights {
            // train emits fresh params/cb as its first outputs; the bundle
            // absorbs exactly these tensors, so keying the cache on them
            // makes the next step a hit without re-parsing
            self.seed_cache(&outputs, n_weights, nw);
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::StateBundle;

    #[test]
    fn every_artifact_loads_and_specs_are_valid() {
        let b = NativeBackend::new();
        for name in b.artifact_names() {
            let exe = b.load(&name).unwrap();
            let spec = exe.spec();
            assert!(!spec.inputs.is_empty(), "{name}");
            assert!(!spec.outputs.is_empty(), "{name}");
            // zero inputs assemble cleanly for every artifact
            let bundle = StateBundle::zeros_for(spec);
            let inputs = bundle.assemble(spec).unwrap();
            assert_eq!(inputs.len(), spec.inputs.len());
        }
    }

    #[test]
    fn decode_runs_and_advances_position() {
        let b = NativeBackend::new();
        let exe = b.load("quickstart.decode").unwrap();
        let mut bundle = StateBundle::zeros_for(exe.spec());
        bundle.set_named(b.init_state("quickstart").unwrap());
        let batch = exe.spec().config.batch_size;
        bundle.set_group(
            "token",
            vec![HostTensor::from_i32(&[batch], &vec![65; batch])],
        );
        let inputs = bundle.assemble(exe.spec()).unwrap();
        let outputs = exe.run(&inputs).unwrap();
        bundle.absorb(exe.spec(), outputs).unwrap();
        let logits = &bundle.group("logits").unwrap()[0];
        assert_eq!(logits.shape, vec![batch, exe.spec().config.vocab_size]);
        assert!(logits.as_f32().unwrap().iter().all(|x| x.is_finite()));
        let pos = bundle.group("state").unwrap()[0].as_i32().unwrap();
        assert_eq!(pos, vec![1; batch]);
    }

    #[test]
    fn decode_is_deterministic() {
        let b = NativeBackend::new();
        let exe = b.load("quickstart.decode").unwrap();
        let run_once = || {
            let mut bundle = StateBundle::zeros_for(exe.spec());
            bundle.set_named(b.init_state("quickstart").unwrap());
            let batch = exe.spec().config.batch_size;
            let mut last = Vec::new();
            for t in 0..5 {
                bundle.set_group(
                    "token",
                    vec![HostTensor::from_i32(&[batch], &vec![10 + t; batch])],
                );
                let inputs = bundle.assemble(exe.spec()).unwrap();
                let outputs = exe.run(&inputs).unwrap();
                bundle.absorb(exe.spec(), outputs).unwrap();
                last = bundle.group("logits").unwrap()[0].as_f32().unwrap();
            }
            last
        };
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn init_state_resolves_for_bench_names() {
        let b = NativeBackend::new();
        assert!(b.init_state("tput-shga-vq-matmul-T256").is_ok());
        assert!(b.init_state("quickstart").is_ok());
        assert!(b.init_state("nope").is_err());
    }

    #[test]
    fn full_presets_have_no_decode() {
        let b = NativeBackend::new();
        assert!(b.has_artifact("enwik8-tiny-full.train"));
        assert!(!b.has_artifact("enwik8-tiny-full.decode"));
        assert!(!b.has_artifact("enwik8-tiny-full.prefill"));
        assert!(b.has_artifact("quickstart.prefill"));
    }

    /// The prefill entry must be an exact multi-token transliteration of
    /// the decode recurrence: ingesting a prompt in one chunked call gives
    /// bit-identical state and last-token logits to feeding the same
    /// tokens one decode step at a time, and rows with lens == 0 pass
    /// through completely untouched.
    #[test]
    fn prefill_matches_stepwise_decode_and_skips_inactive_lanes() {
        let b = NativeBackend::new();
        let decode = b.load("quickstart.decode").unwrap();
        let prefill = b.load("quickstart.prefill").unwrap();
        let batch = decode.spec().config.batch_size;
        let vocab = decode.spec().config.vocab_size;
        let chunk = Layout::new(decode.spec().config.clone()).prefill_chunk();
        // prompt longer than one block so the window wraps and the cache
        // folds at least once, shorter than the chunk so one call ingests it
        let prompt: Vec<i32> = (0..chunk as i32 - 3).map(|t| (t * 7 + 13) % 251).collect();

        // --- stepwise reference: feed every row the prompt token by token
        let mut ref_bundle = StateBundle::zeros_for(decode.spec());
        ref_bundle.set_named(b.init_state("quickstart").unwrap());
        let mut ref_logits = Vec::new();
        for &t in &prompt {
            ref_bundle.set_group("token", vec![HostTensor::from_i32(&[batch], &vec![t; batch])]);
            let inputs = ref_bundle.assemble(decode.spec()).unwrap();
            let outputs = decode.run(&inputs).unwrap();
            ref_bundle.absorb(decode.spec(), outputs).unwrap();
            ref_logits = ref_bundle.group("logits").unwrap()[0].as_f32().unwrap();
        }

        // --- prefill: rows 0 and 2 ingest the prompt in one call; 1 and 3 idle
        let mut bundle = StateBundle::zeros_for(prefill.spec());
        bundle.set_named(b.init_state("quickstart").unwrap());
        let mut toks = vec![0i32; batch * chunk];
        let mut lens = vec![0i32; batch];
        for row in [0usize, 2] {
            toks[row * chunk..row * chunk + prompt.len()].copy_from_slice(&prompt);
            lens[row] = prompt.len() as i32;
        }
        bundle.set_group("tokens", vec![HostTensor::from_i32(&[batch, chunk], &toks)]);
        bundle.set_group("lens", vec![HostTensor::from_i32(&[batch], &lens)]);
        let inputs = bundle.assemble(prefill.spec()).unwrap();
        let outputs = prefill.run(&inputs).unwrap();
        bundle.absorb(prefill.spec(), outputs).unwrap();

        let logits = bundle.group("logits").unwrap()[0].as_f32().unwrap();
        assert_eq!(
            &logits[0..vocab],
            &ref_logits[0..vocab],
            "prefill logits differ from stepwise decode"
        );
        // active rows reach pos = prompt len, idle rows stay untouched at 0
        let pos = bundle.group("state").unwrap()[0].as_i32().unwrap();
        assert_eq!(pos, vec![prompt.len() as i32, 0, prompt.len() as i32, 0]);
        assert!(logits[vocab..2 * vocab].iter().all(|&x| x == 0.0));
        // per-row state of an active row matches the stepwise reference
        let ref_state = ref_bundle.group("state").unwrap();
        let new_state = bundle.group("state").unwrap();
        for (r, n) in ref_state.iter().zip(new_state.iter()).skip(1) {
            let stride = r.data.len() / batch;
            assert_eq!(
                r.data[..stride],
                n.data[..stride],
                "row-0 state leaf diverged from stepwise decode"
            );
            assert!(
                n.data[stride..2 * stride].iter().all(|&x| x == 0),
                "idle row-1 state was touched"
            );
        }
    }

    #[test]
    fn weight_cache_keys_on_identity_and_never_serves_stale_weights() {
        let b = NativeBackend::new();
        let exe = b.load("quickstart.decode").unwrap();
        let mut bundle = StateBundle::zeros_for(exe.spec());
        bundle.set_named(b.init_state("quickstart").unwrap());
        let batch = exe.spec().config.batch_size;
        bundle.set_group(
            "token",
            vec![HostTensor::from_i32(&[batch], &vec![65; batch])],
        );
        let inputs = bundle.assemble(exe.spec()).unwrap();
        // first call parses, second hits the cache (same buffer identities)
        let out1 = exe.run(&inputs).unwrap();
        let out2 = exe.run(&inputs).unwrap();
        assert_eq!(out1.last().unwrap(), out2.last().unwrap(), "cache changed results");
        // replacing a weight tensor (new identity, new content) must
        // invalidate the cache, not serve the stale parse
        let mut inputs2 = inputs.clone();
        let shape = inputs2[0].shape.clone();
        let mut w = inputs2[0].as_f32().unwrap();
        for x in w.iter_mut() {
            *x += 1.0;
        }
        inputs2[0] = HostTensor::from_f32(&shape, &w);
        let out3 = exe.run(&inputs2).unwrap();
        assert_ne!(
            out1.last().unwrap().as_f32().unwrap(),
            out3.last().unwrap().as_f32().unwrap(),
            "executor served stale cached weights"
        );
    }
}
