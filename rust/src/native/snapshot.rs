//! Versioned binary snapshots of per-lane decode state (DESIGN.md §10).
//!
//! Transformer-VQ's decode state is *fixed size* (Thm 3.7): a rolling
//! `2L` window plus the `S`-slot compressive cache per layer, regardless
//! of how many tokens a lane has consumed. That makes a lane snapshot an
//! O(model) value — it can be stored, restored, forked, or migrated to
//! another process, and the restored lane continues **bit-identically**
//! to the uninterrupted run (pinned by `rust/tests/snapshot_oracle.rs`
//! across SimdMode × Precision × batched/per-lane × thread count).
//!
//! A [`LaneSnapshot`] captures one batch row: `pos` plus every state leaf
//! (`win_k`/`win_v`/`win_z`, `cache_u`/`cache_l` per layer), and the
//! serving-side stream extras a migration needs — the sampling RNG state,
//! the [`crate::tokenizer::Utf8Stream`] remainder, and the generated-token
//! tail that stop-sequence matching inspects. A [`SessionSnapshot`] is all
//! `B` lanes of a session. What is deliberately *not* captured: weights
//! and codebooks (re-derived from the checkpoint at restore; the config
//! guard plus same-(SIMD × precision) restore keeps bit-identity), scratch
//! arenas (pure caches), and engine bookkeeping like wall-clock deadlines.
//!
//! ## Wire format (version 1, little-endian)
//!
//! ```text
//! lane record:                      session record:
//!   magic   b"TVQS"                  magic   b"TVQM"
//!   version u32 = 1                  version u32 = 1
//!   config  8 × u32 guard            lanes   u32
//!   flags   u32 (bit0 = rng)         per lane: u32 len + lane record
//!   pos     i32                      fnv64   u64 checksum
//!   per layer: win_k f32[..],
//!     win_v f32[..], win_z i32[..],
//!     cache_u f32[..], cache_l f32[..]
//!   rng     4 × u64 (iff bit0)
//!   utf8    u32 len + bytes
//!   stop    u32 len + i32[..]
//!   fnv64   u64 checksum
//! ```
//!
//! The config guard is `(n_layers, n_heads, d_k, d_v, n_code, block_len,
//! vocab_size, use_cache)` — every dimension the state leaf sizes derive
//! from — so a snapshot can never be silently applied to a mismatched
//! model. The trailing checksum is FNV-1a-64 over all preceding bytes;
//! each FNV step is a bijection of the hash state, so *any* single-byte
//! corruption is detected. Decoding is total: truncated, bit-flipped,
//! wrong-version, or wrong-config bytes produce a clean `Err`, never a
//! panic or partial state mutation (property-tested in
//! `rust/tests/proptests.rs`).

use anyhow::{bail, Result};

use crate::manifest::ModelConfig;
use crate::tensor::HostTensor;

use super::model::State;

const LANE_MAGIC: &[u8; 4] = b"TVQS";
const SESSION_MAGIC: &[u8; 4] = b"TVQM";
const VERSION: u32 = 1;
const FLAG_RNG: u32 = 1;
/// Sanity bound on the UTF-8 remainder (a real decoder holds ≤ 3 bytes).
const MAX_UTF8_PENDING: usize = 64;
/// Sanity bound on the stop-sequence tail carried for match progress.
const MAX_STOP_TAIL: usize = 4096;

/// One layer of one lane's recurrent state (per-lane sizes, i.e. the
/// `[B, ...]` leaves with the batch dimension stripped).
#[derive(Debug, Clone, PartialEq)]
pub struct LaneLayer {
    /// Rolling key window, `[2L, H, d_k]`.
    pub win_k: Vec<f32>,
    /// Rolling value window, `[2L, H, d_v]`.
    pub win_v: Vec<f32>,
    /// Window shortcodes, `[2L, H]`.
    pub win_z: Vec<i32>,
    /// Compressive cache values, `[H, S, d_v]`.
    pub cache_u: Vec<f32>,
    /// Compressive cache counts, `[H, S]`.
    pub cache_l: Vec<f32>,
}

/// One batch lane's complete decode state as a value: model recurrence
/// plus the serving-stream extras (RNG, UTF-8 remainder, stop tail).
/// Encode with [`LaneSnapshot::encode`]; the session/sampler layers fill
/// the extras before encoding and re-apply them after decoding.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneSnapshot {
    /// Tokens ingested since reset.
    pub pos: i32,
    /// Per-layer recurrent state, outermost layer first.
    pub layers: Vec<LaneLayer>,
    /// xoshiro256** sampling-stream state, if the lane carries one.
    pub rng: Option<[u64; 4]>,
    /// Undecoded UTF-8 tail held by the lane's streaming decoder.
    pub utf8_pending: Vec<u8>,
    /// Recent generated tokens, newest last — enough to resume
    /// stop-sequence matching (`generated.ends_with(seq)`).
    pub stop_tail: Vec<i32>,
}

/// All lanes of one session, restorable into any same-config session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// One snapshot per batch row, row order.
    pub lanes: Vec<LaneSnapshot>,
}

/// Per-lane element counts of the five state leaves, derived from config:
/// `(win_k, win_v, win_z, cache_u, cache_l)`.
fn lane_dims(cfg: &ModelConfig) -> (usize, usize, usize, usize, usize) {
    let w2l = 2 * cfg.block_len;
    let (h, s) = (cfg.n_heads, cfg.n_code);
    (w2l * h * cfg.d_k, w2l * h * cfg.d_v, w2l * h, h * s * cfg.d_v, h * s)
}

/// The 8-word config guard written into every lane record.
fn config_guard(cfg: &ModelConfig) -> [u32; 8] {
    [
        cfg.n_layers as u32,
        cfg.n_heads as u32,
        cfg.d_k as u32,
        cfg.d_v as u32,
        cfg.n_code as u32,
        cfg.block_len as u32,
        cfg.vocab_size as u32,
        cfg.use_cache as u32,
    ]
}

const GUARD_NAMES: [&str; 8] =
    ["n_layers", "n_heads", "d_k", "d_v", "n_code", "block_len", "vocab_size", "use_cache"];

/// 64-bit FNV-1a. Every step is a bijection of the running state, so any
/// single-byte difference in the input changes the digest.
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

// ---------------------------------------------------------------------------
// byte writer / bounds-checked reader
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(out: &mut Vec<u8>, xs: &[i32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Cursor over untrusted bytes: every read is bounds-checked and errors
/// cleanly on shortfall.
struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.off..end];
                self.off = end;
                Ok(s)
            }
            None => bail!(
                "snapshot truncated: need {n} bytes at offset {}, have {}",
                self.off,
                self.buf.len() - self.off
            ),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(self.u32()? as i32)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let b = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.off != self.buf.len() {
            bail!("snapshot has {} trailing bytes after the payload", self.buf.len() - self.off);
        }
        Ok(())
    }
}

/// Split off and verify the trailing FNV-1a-64 checksum; returns the
/// payload it covers.
fn checked_payload<'a>(bytes: &'a [u8], what: &str) -> Result<&'a [u8]> {
    if bytes.len() < 8 {
        bail!("{what} snapshot too short for a checksum ({} bytes)", bytes.len());
    }
    let (payload, tail) = bytes.split_at(bytes.len() - 8);
    let mut a = [0u8; 8];
    a.copy_from_slice(tail);
    let stored = u64::from_le_bytes(a);
    let computed = fnv64(payload);
    if stored != computed {
        bail!("{what} snapshot checksum mismatch (corrupt or truncated bytes)");
    }
    Ok(payload)
}

/// Verify magic + version + config guard at the head of `r`.
fn check_header(r: &mut Reader<'_>, cfg: &ModelConfig) -> Result<()> {
    let magic = r.take(4)?;
    if magic != LANE_MAGIC {
        bail!("not a lane snapshot (bad magic {magic:02x?})");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported lane snapshot version {version} (this build reads {VERSION})");
    }
    let want = config_guard(cfg);
    for (name, &w) in GUARD_NAMES.iter().zip(&want) {
        let got = r.u32()?;
        if got != w {
            bail!("snapshot config mismatch: {name} is {got}, this model has {w}");
        }
    }
    Ok(())
}

impl LaneSnapshot {
    /// Validate that the leaf sizes agree with `cfg` (encode-side guard so
    /// a mis-built snapshot can never produce undecodable bytes).
    fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        if self.layers.len() != cfg.n_layers {
            bail!("lane snapshot has {} layers, config has {}", self.layers.len(), cfg.n_layers);
        }
        let (wk, wv, wz, cu, cl) = lane_dims(cfg);
        for (l, lay) in self.layers.iter().enumerate() {
            let sizes = [
                (lay.win_k.len(), wk, "win_k"),
                (lay.win_v.len(), wv, "win_v"),
                (lay.win_z.len(), wz, "win_z"),
                (lay.cache_u.len(), cu, "cache_u"),
                (lay.cache_l.len(), cl, "cache_l"),
            ];
            for (got, want, name) in sizes {
                if got != want {
                    bail!("lane snapshot layer {l}: {name} has {got} elems, config wants {want}");
                }
            }
        }
        if self.utf8_pending.len() > MAX_UTF8_PENDING {
            bail!("lane snapshot utf8 remainder is {} bytes (max {MAX_UTF8_PENDING})", self.utf8_pending.len());
        }
        if self.stop_tail.len() > MAX_STOP_TAIL {
            bail!("lane snapshot stop tail is {} tokens (max {MAX_STOP_TAIL})", self.stop_tail.len());
        }
        Ok(())
    }

    /// Serialize to the version-1 lane record (see the module docs).
    pub fn encode(&self, cfg: &ModelConfig) -> Result<Vec<u8>> {
        self.validate(cfg)?;
        let mut out = Vec::new();
        out.extend_from_slice(LANE_MAGIC);
        put_u32(&mut out, VERSION);
        for w in config_guard(cfg) {
            put_u32(&mut out, w);
        }
        let flags = if self.rng.is_some() { FLAG_RNG } else { 0 };
        put_u32(&mut out, flags);
        put_u32(&mut out, self.pos as u32);
        for lay in &self.layers {
            put_f32s(&mut out, &lay.win_k);
            put_f32s(&mut out, &lay.win_v);
            put_i32s(&mut out, &lay.win_z);
            put_f32s(&mut out, &lay.cache_u);
            put_f32s(&mut out, &lay.cache_l);
        }
        if let Some(s) = self.rng {
            for w in s {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        put_u32(&mut out, self.utf8_pending.len() as u32);
        out.extend_from_slice(&self.utf8_pending);
        put_u32(&mut out, self.stop_tail.len() as u32);
        put_i32s(&mut out, &self.stop_tail);
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        Ok(out)
    }

    /// Decode a version-1 lane record for a model running `cfg`. Total on
    /// hostile input: truncation, corruption, version skew, and config
    /// mismatch all produce clean errors.
    pub fn decode(cfg: &ModelConfig, bytes: &[u8]) -> Result<Self> {
        let payload = checked_payload(bytes, "lane")?;
        let mut r = Reader::new(payload);
        check_header(&mut r, cfg)?;
        let flags = r.u32()?;
        if flags & !FLAG_RNG != 0 {
            bail!("lane snapshot has unknown flag bits {:#x}", flags & !FLAG_RNG);
        }
        let pos = r.i32()?;
        let (wk, wv, wz, cu, cl) = lane_dims(cfg);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LaneLayer {
                win_k: r.f32s(wk)?,
                win_v: r.f32s(wv)?,
                win_z: r.i32s(wz)?,
                cache_u: r.f32s(cu)?,
                cache_l: r.f32s(cl)?,
            });
        }
        let rng = if flags & FLAG_RNG != 0 {
            Some([r.u64()?, r.u64()?, r.u64()?, r.u64()?])
        } else {
            None
        };
        let n_utf8 = r.u32()? as usize;
        if n_utf8 > MAX_UTF8_PENDING {
            bail!("lane snapshot utf8 remainder claims {n_utf8} bytes (max {MAX_UTF8_PENDING})");
        }
        let utf8_pending = r.take(n_utf8)?.to_vec();
        let n_stop = r.u32()? as usize;
        if n_stop > MAX_STOP_TAIL {
            bail!("lane snapshot stop tail claims {n_stop} tokens (max {MAX_STOP_TAIL})");
        }
        let stop_tail = r.i32s(n_stop)?;
        r.done()?;
        Ok(Self { pos, layers, rng, utf8_pending, stop_tail })
    }

    /// Capture lane `lane` of a native [`State`] (extras left empty).
    pub(crate) fn from_state(cfg: &ModelConfig, st: &State, lane: usize) -> Result<Self> {
        let b = st.pos.len();
        if lane >= b {
            bail!("snapshot lane {lane} out of range (batch {b})");
        }
        let row = |v: &[f32]| -> Vec<f32> {
            let stride = v.len() / b;
            v[lane * stride..(lane + 1) * stride].to_vec()
        };
        let row_i = |v: &[i32]| -> Vec<i32> {
            let stride = v.len() / b;
            v[lane * stride..(lane + 1) * stride].to_vec()
        };
        let snap = Self {
            pos: st.pos[lane],
            layers: st
                .layers
                .iter()
                .map(|l| LaneLayer {
                    win_k: row(&l.win_k),
                    win_v: row(&l.win_v),
                    win_z: row_i(&l.win_z),
                    cache_u: row(&l.cache_u),
                    cache_l: row(&l.cache_l),
                })
                .collect(),
            rng: None,
            utf8_pending: Vec::new(),
            stop_tail: Vec::new(),
        };
        snap.validate(cfg)?;
        Ok(snap)
    }

    /// Overwrite lane `lane` of a native [`State`] with this snapshot.
    /// Validates fully before writing, so a mismatched snapshot never
    /// leaves the lane half-mutated.
    pub(crate) fn apply_to_state(&self, cfg: &ModelConfig, st: &mut State, lane: usize) -> Result<()> {
        self.validate(cfg)?;
        let b = st.pos.len();
        if lane >= b {
            bail!("restore lane {lane} out of range (batch {b})");
        }
        if st.layers.len() != self.layers.len() {
            bail!("state has {} layers, snapshot has {}", st.layers.len(), self.layers.len());
        }
        st.pos[lane] = self.pos;
        for (dst, src) in st.layers.iter_mut().zip(&self.layers) {
            write_row(&mut dst.win_k, b, lane, &src.win_k)?;
            write_row(&mut dst.win_v, b, lane, &src.win_v)?;
            write_row_i(&mut dst.win_z, b, lane, &src.win_z)?;
            write_row(&mut dst.cache_u, b, lane, &src.cache_u)?;
            write_row(&mut dst.cache_l, b, lane, &src.cache_l)?;
        }
        Ok(())
    }

    /// Capture lane `lane` from state-group tensors in leaf order (`pos`,
    /// then `win_k, win_v, win_z, cache_u, cache_l` per layer — the order
    /// of `Layout::state_leaves` and `StateBundle`'s "state" group).
    pub fn from_tensors(cfg: &ModelConfig, tensors: &[HostTensor], lane: usize) -> Result<Self> {
        let st = State::parse(cfg, tensors)?;
        Self::from_state(cfg, &st, lane)
    }

    /// Overwrite lane `lane` of state-group tensors (same leaf order as
    /// [`LaneSnapshot::from_tensors`]) in place, byte-exactly.
    pub fn apply_to_tensors(
        &self,
        cfg: &ModelConfig,
        tensors: &mut [HostTensor],
        lane: usize,
    ) -> Result<()> {
        self.validate(cfg)?;
        let expected = 1 + 5 * cfg.n_layers;
        if tensors.len() != expected {
            bail!("state group has {} tensors, expected {expected}", tensors.len());
        }
        let b = cfg.batch_size;
        if lane >= b {
            bail!("restore lane {lane} out of range (batch {b})");
        }
        write_tensor_row_i32(&mut tensors[0], b, lane, &[self.pos])?;
        for (l, lay) in self.layers.iter().enumerate() {
            let base = 1 + 5 * l;
            write_tensor_row_f32(&mut tensors[base], b, lane, &lay.win_k)?;
            write_tensor_row_f32(&mut tensors[base + 1], b, lane, &lay.win_v)?;
            write_tensor_row_i32(&mut tensors[base + 2], b, lane, &lay.win_z)?;
            write_tensor_row_f32(&mut tensors[base + 3], b, lane, &lay.cache_u)?;
            write_tensor_row_f32(&mut tensors[base + 4], b, lane, &lay.cache_l)?;
        }
        Ok(())
    }
}

fn write_row(dst: &mut [f32], b: usize, lane: usize, src: &[f32]) -> Result<()> {
    let stride = dst.len() / b;
    if stride != src.len() {
        bail!("state row stride {stride} != snapshot leaf len {}", src.len());
    }
    dst[lane * stride..(lane + 1) * stride].copy_from_slice(src);
    Ok(())
}

fn write_row_i(dst: &mut [i32], b: usize, lane: usize, src: &[i32]) -> Result<()> {
    let stride = dst.len() / b;
    if stride != src.len() {
        bail!("state row stride {stride} != snapshot leaf len {}", src.len());
    }
    dst[lane * stride..(lane + 1) * stride].copy_from_slice(src);
    Ok(())
}

fn write_tensor_row_f32(t: &mut HostTensor, b: usize, lane: usize, vals: &[f32]) -> Result<()> {
    let stride = t.data.len() / b;
    if stride != vals.len() * 4 {
        bail!("state leaf row is {stride} bytes, snapshot leaf is {} f32s", vals.len());
    }
    let mut bytes = Vec::with_capacity(stride);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    t.data[lane * stride..(lane + 1) * stride].copy_from_slice(&bytes);
    Ok(())
}

fn write_tensor_row_i32(t: &mut HostTensor, b: usize, lane: usize, vals: &[i32]) -> Result<()> {
    let stride = t.data.len() / b;
    if stride != vals.len() * 4 {
        bail!("state leaf row is {stride} bytes, snapshot leaf is {} i32s", vals.len());
    }
    let mut bytes = Vec::with_capacity(stride);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    t.data[lane * stride..(lane + 1) * stride].copy_from_slice(&bytes);
    Ok(())
}

impl SessionSnapshot {
    /// Serialize all lanes to the version-1 session record.
    pub fn encode(&self, cfg: &ModelConfig) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        out.extend_from_slice(SESSION_MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, self.lanes.len() as u32);
        for lane in &self.lanes {
            let blob = lane.encode(cfg)?;
            put_u32(&mut out, blob.len() as u32);
            out.extend_from_slice(&blob);
        }
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        Ok(out)
    }

    /// Decode a version-1 session record for a model running `cfg`. Total
    /// on hostile input, like [`LaneSnapshot::decode`].
    pub fn decode(cfg: &ModelConfig, bytes: &[u8]) -> Result<Self> {
        let payload = checked_payload(bytes, "session")?;
        let mut r = Reader::new(payload);
        let magic = r.take(4)?;
        if magic != SESSION_MAGIC {
            bail!("not a session snapshot (bad magic {magic:02x?})");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported session snapshot version {version} (this build reads {VERSION})");
        }
        let n = r.u32()? as usize;
        // each lane record is > 48 header bytes; bound n before allocating
        if n > payload.len() / 48 {
            bail!("session snapshot claims {n} lanes in {} bytes", payload.len());
        }
        let mut lanes = Vec::with_capacity(n);
        for _ in 0..n {
            let len = r.u32()? as usize;
            let blob = r.take(len)?;
            lanes.push(LaneSnapshot::decode(cfg, blob)?);
        }
        r.done()?;
        Ok(Self { lanes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::preset_config;

    fn sample_lane(cfg: &ModelConfig, salt: i32) -> LaneSnapshot {
        let (wk, wv, wz, cu, cl) = lane_dims(cfg);
        let f = |n: usize, k: i32| -> Vec<f32> {
            (0..n).map(|i| (i as f32 + k as f32) * 0.25 - 3.0).collect()
        };
        let iv = |n: usize, k: i32| -> Vec<i32> { (0..n).map(|i| i as i32 % 7 + k).collect() };
        LaneSnapshot {
            pos: 41 + salt,
            layers: (0..cfg.n_layers)
                .map(|l| LaneLayer {
                    win_k: f(wk, salt + l as i32),
                    win_v: f(wv, salt + 2 * l as i32),
                    win_z: iv(wz, salt),
                    cache_u: f(cu, salt + 3),
                    cache_l: f(cl, salt + 4),
                })
                .collect(),
            rng: Some([1, 2, 3, 0xdead_beef + salt as u64]),
            utf8_pending: vec![0xC3],
            stop_tail: vec![104, 105, salt],
        }
    }

    #[test]
    fn lane_roundtrip_is_identity() {
        let cfg = preset_config("quickstart").unwrap();
        let snap = sample_lane(&cfg, 5);
        let bytes = snap.encode(&cfg).unwrap();
        let back = LaneSnapshot::decode(&cfg, &bytes).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn session_roundtrip_is_identity() {
        let cfg = preset_config("quickstart").unwrap();
        let sess = SessionSnapshot {
            lanes: (0..cfg.batch_size).map(|i| sample_lane(&cfg, i as i32)).collect(),
        };
        let bytes = sess.encode(&cfg).unwrap();
        assert_eq!(SessionSnapshot::decode(&cfg, &bytes).unwrap(), sess);
    }

    #[test]
    fn every_truncation_errors_cleanly() {
        let cfg = preset_config("quickstart").unwrap();
        let bytes = sample_lane(&cfg, 1).encode(&cfg).unwrap();
        for keep in [0, 3, 7, 11, 47, 48, bytes.len() / 2, bytes.len() - 1] {
            assert!(LaneSnapshot::decode(&cfg, &bytes[..keep]).is_err(), "keep={keep}");
        }
    }

    #[test]
    fn bit_flips_fail_the_checksum() {
        let cfg = preset_config("quickstart").unwrap();
        let bytes = sample_lane(&cfg, 2).encode(&cfg).unwrap();
        for byte_ix in [0usize, 5, 40, bytes.len() / 3, bytes.len() - 9, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[byte_ix] ^= 0x10;
            assert!(LaneSnapshot::decode(&cfg, &bad).is_err(), "flip at {byte_ix}");
        }
    }

    #[test]
    fn version_and_config_mismatch_error() {
        let cfg = preset_config("quickstart").unwrap();
        let snap = sample_lane(&cfg, 3);
        // re-encode with a bumped version and a fixed-up checksum: the
        // structural version check must fire, not the corruption check
        let mut bytes = snap.encode(&cfg).unwrap();
        bytes[4] = 2;
        let len = bytes.len();
        let sum = fnv64(&bytes[..len - 8]);
        bytes[len - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = LaneSnapshot::decode(&cfg, &bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // a config with different dims must be rejected by the guard
        let other = preset_config("ablate-S64").unwrap();
        let good = snap.encode(&cfg).unwrap();
        let err = LaneSnapshot::decode(&other, &good).unwrap_err();
        assert!(err.to_string().contains("config mismatch"), "{err}");
    }

    #[test]
    fn lane_magic_is_not_a_session() {
        let cfg = preset_config("quickstart").unwrap();
        let bytes = sample_lane(&cfg, 4).encode(&cfg).unwrap();
        assert!(SessionSnapshot::decode(&cfg, &bytes).is_err());
    }
}
