//! Compute kernels for the native backend: cache-blocked matmuls with
//! unrolled, auto-vectorizable inner loops, plus the persistent thread pool
//! behind the engine's batch-lane parallelism.
//!
//! # Kernel design
//!
//! Every matmul-family call site in the native engine (`model`, `step`,
//! `autodiff`) routes through this module, so loop order, tiling, and
//! unrolling decisions live in exactly one place. The f32 serving-path
//! call sites dispatch through [`super::simd::SimdMode`], which selects
//! between these scalar kernels and their AVX2+FMA twins once at executor
//! init (`TVQ_SIMD=0` forces scalar). All kernels operate on
//! flat row-major slices and are individually sequential and deterministic:
//! for a fixed input, the floating-point accumulation order never depends
//! on the thread count, which is what lets the engine promise bit-identical
//! results at `num_threads = 1` and `num_threads = N` (asserted by
//! `rust/tests/parallel_determinism.rs`).
//!
//! The panel sizes [`TILE_K`] × [`TILE_N`] are chosen so one f32 panel of
//! the right-hand matrix (the streamed operand) fits in a 32 KiB L1 data
//! cache; see `DESIGN.md` §7 ("Performance model") for the derivation and
//! the measured scaling curves.
//!
//! # Parallelism
//!
//! [`parallel_for`] / [`parallel_for_items`] execute an index space on a
//! lazily spawned, process-global pool of parked worker threads (plain
//! `std::thread` — the deployment image vendors no rayon, so the pool is
//! ~100 lines of std). Work items are claimed with an atomic counter, so
//! scheduling is dynamic, but each item is executed exactly once by exactly
//! one thread and items never share mutable state — results cannot depend
//! on the schedule. Dispatch latency is a few microseconds per call; split
//! points in the engine are chosen so the work quantum per item (a whole
//! batch row per step, a whole output-row block per GEMM) is far above
//! that (see `DESIGN.md` §7).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::tensor::bf16_to_f32;

// ---------------------------------------------------------------------------
// tiling parameters
// ---------------------------------------------------------------------------

/// Rows of the right-hand operand (the `k` dimension) per cache block.
///
/// One f32 panel of `TILE_K × TILE_N` elements is 32 KiB — sized to sit in
/// a typical L1 data cache while it is streamed over every output row.
pub const TILE_K: usize = 64;

/// Columns of the right-hand operand (the `n` dimension) per cache block.
pub const TILE_N: usize = 128;

// ---------------------------------------------------------------------------
// f32 kernels (forward / serving path)
// ---------------------------------------------------------------------------

/// Dot product of two equal-length f32 slices.
///
/// Loop order: single pass, 4-way unrolled into independent partial sums
/// (breaks the serial FP dependence chain so the backend can keep ~4 FMAs
/// in flight / vectorize). Complexity O(n); accumulation order is fixed.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    while i < a.len() {
        acc += a[i] * b[i];
        i += 1;
    }
    acc
}

/// `out = x @ w`, with `w` row-major `[x.len(), out.len()]`. Overwrites out.
///
/// Loop order: k (rows of `w`, 4-way unrolled) outer, contiguous n inner —
/// an axpy formulation that walks `w` exactly once in storage order, so the
/// inner loop is a unit-stride multiply-add the compiler auto-vectorizes.
/// Complexity O(k·n). The `k` dimension here is `d_model`-sized, so no
/// k-blocking is needed: the accumulator `out` itself stays resident.
pub fn matvec(w: &[f32], x: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    matvec_add(w, x, out);
}

/// `out += x @ w` (residual add), same layout and loop order as [`matvec`].
pub fn matvec_add(w: &[f32], x: &[f32], out: &mut [f32]) {
    let n = out.len();
    let k = x.len();
    debug_assert_eq!(w.len(), k * n);
    let mut i = 0;
    while i + 4 <= k {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
            i += 4;
            continue;
        }
        let r0 = &w[i * n..(i + 1) * n];
        let r1 = &w[(i + 1) * n..(i + 2) * n];
        let r2 = &w[(i + 2) * n..(i + 3) * n];
        let r3 = &w[(i + 3) * n..(i + 4) * n];
        for (j, o) in out.iter_mut().enumerate() {
            *o += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
        }
        i += 4;
    }
    while i < k {
        let xi = x[i];
        if xi != 0.0 {
            let row = &w[i * n..(i + 1) * n];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * wv;
            }
        }
        i += 1;
    }
}

/// `c = a @ b`: row-major `a [m,k]`, `b [k,n]`, `c [m,n]`. Overwrites `c`.
///
/// Cache-blocked: loop order is k-block ([`TILE_K`]) → n-block
/// ([`TILE_N`]) → output row `i` → unrolled k micro-step → contiguous j.
/// The active `b` panel (`TILE_K × TILE_N` = 32 KiB) stays L1-resident
/// while it is reused across all `m` output rows; `a` is read in storage
/// order; `c` rows accumulate in place. Complexity O(m·k·n). Each output
/// row's accumulation order is a function of the loop structure only —
/// never of how rows are distributed over threads — so the row-banded
/// [`super::simd::SimdMode::gemm_par`] is bit-identical to this kernel.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    gemm_add(m, k, n, a, b, c);
}

/// `c += a @ b`, same layout, blocking, and loop order as [`gemm`].
pub fn gemm_add(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TILE_N).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j1];
                let mut kk = k0;
                while kk + 4 <= k1 {
                    let (x0, x1, x2, x3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                    let r0 = &b[kk * n + j0..kk * n + j1];
                    let r1 = &b[(kk + 1) * n + j0..(kk + 1) * n + j1];
                    let r2 = &b[(kk + 2) * n + j0..(kk + 2) * n + j1];
                    let r3 = &b[(kk + 3) * n + j0..(kk + 3) * n + j1];
                    for (j, o) in crow.iter_mut().enumerate() {
                        *o += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
                    }
                    kk += 4;
                }
                while kk < k1 {
                    let xi = arow[kk];
                    if xi != 0.0 {
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for (o, &bv) in crow.iter_mut().zip(brow) {
                            *o += xi * bv;
                        }
                    }
                    kk += 1;
                }
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// Index of the nearest codebook row (L2) among `s` rows of width `dk`:
/// one squared-distance pass per row, strict `<` so the first of tied rows
/// wins. This is the scalar reference for the quantizer scan; the AVX2
/// twin lives in [`super::simd`]. Complexity O(s·dk).
pub fn nearest_code(x: &[f32], codebook: &[f32], s: usize, dk: usize) -> usize {
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for c in 0..s {
        let row = &codebook[c * dk..(c + 1) * dk];
        let mut d = 0.0f32;
        for (a, b) in x.iter().zip(row) {
            let t = a - b;
            d += t * t;
        }
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// reduced-precision kernels: bf16 / int8 weights, f32 accumulation
// ---------------------------------------------------------------------------
//
// Twins of the f32 kernels above for quantized *weight* operands (the
// streamed right-hand matrix); activations and accumulators stay f32, and
// loop structure, unrolling, and accumulation order mirror the f32 kernels
// exactly, so every per-mode bit-determinism argument carries over.
//
// bf16 widens by zero-extending the mantissa ([`bf16_to_f32`], a bit
// shift), which makes these kernels *bit-identical* to the f32 kernels run
// on the dequantized weights. int8 folds the per-k-row scale into the
// broadcast activation scalar (`x[i] * scale[i]`), keeping one multiply
// per inner-loop element; that folding reassociates one multiplication
// (`(x·s)·q` vs `x·(s·q)`), so int8 results agree with f32-on-dequantized
// to rounding tolerance rather than bitwise — still bit-deterministic
// within the mode. The int8 codebook scan performs no such folding
// (`x - s·q` is exactly the dequantized subtraction), so its distances and
// argmin match the f32 scan over the dequantized codebook bit for bit.

/// bf16 twin of [`matvec_add`]: `out += x @ w` with `w` stored as bf16,
/// row-major `[x.len(), out.len()]`. Bit-identical to
/// `matvec_add(dequantized(w), x, out)`.
pub fn matvec_add_bf16(w: &[u16], x: &[f32], out: &mut [f32]) {
    let n = out.len();
    let k = x.len();
    debug_assert_eq!(w.len(), k * n);
    let mut i = 0;
    while i + 4 <= k {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
            i += 4;
            continue;
        }
        let r0 = &w[i * n..(i + 1) * n];
        let r1 = &w[(i + 1) * n..(i + 2) * n];
        let r2 = &w[(i + 2) * n..(i + 3) * n];
        let r3 = &w[(i + 3) * n..(i + 4) * n];
        for (j, o) in out.iter_mut().enumerate() {
            *o += x0 * bf16_to_f32(r0[j])
                + x1 * bf16_to_f32(r1[j])
                + x2 * bf16_to_f32(r2[j])
                + x3 * bf16_to_f32(r3[j]);
        }
        i += 4;
    }
    while i < k {
        let xi = x[i];
        if xi != 0.0 {
            let row = &w[i * n..(i + 1) * n];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * bf16_to_f32(wv);
            }
        }
        i += 1;
    }
}

/// bf16 twin of [`gemm_add`]: `c += a @ b` with `b` stored as bf16. Same
/// [`TILE_K`] × [`TILE_N`] blocking and loop order.
pub fn gemm_add_bf16(m: usize, k: usize, n: usize, a: &[f32], b: &[u16], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TILE_N).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j1];
                let mut kk = k0;
                while kk + 4 <= k1 {
                    let (x0, x1, x2, x3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                    let r0 = &b[kk * n + j0..kk * n + j1];
                    let r1 = &b[(kk + 1) * n + j0..(kk + 1) * n + j1];
                    let r2 = &b[(kk + 2) * n + j0..(kk + 2) * n + j1];
                    let r3 = &b[(kk + 3) * n + j0..(kk + 3) * n + j1];
                    for (j, o) in crow.iter_mut().enumerate() {
                        *o += x0 * bf16_to_f32(r0[j])
                            + x1 * bf16_to_f32(r1[j])
                            + x2 * bf16_to_f32(r2[j])
                            + x3 * bf16_to_f32(r3[j]);
                    }
                    kk += 4;
                }
                while kk < k1 {
                    let xi = arow[kk];
                    if xi != 0.0 {
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for (o, &bv) in crow.iter_mut().zip(brow) {
                            *o += xi * bf16_to_f32(bv);
                        }
                    }
                    kk += 1;
                }
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// int8 twin of [`matvec_add`]: `out += x @ dequant(w)` with `w` stored as
/// int8 row-major `[x.len(), out.len()]` and one f32 `scale` per k-row.
/// The scale is folded into the broadcast scalar (`x[i] * scale[i]`), so
/// the inner loop stays one multiply-add per element.
pub fn matvec_add_i8(w: &[i8], scale: &[f32], x: &[f32], out: &mut [f32]) {
    let n = out.len();
    let k = x.len();
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(scale.len(), k);
    let mut i = 0;
    while i + 4 <= k {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
            i += 4;
            continue;
        }
        let (s0, s1, s2, s3) =
            (x0 * scale[i], x1 * scale[i + 1], x2 * scale[i + 2], x3 * scale[i + 3]);
        let r0 = &w[i * n..(i + 1) * n];
        let r1 = &w[(i + 1) * n..(i + 2) * n];
        let r2 = &w[(i + 2) * n..(i + 3) * n];
        let r3 = &w[(i + 3) * n..(i + 4) * n];
        for (j, o) in out.iter_mut().enumerate() {
            *o += s0 * (r0[j] as f32)
                + s1 * (r1[j] as f32)
                + s2 * (r2[j] as f32)
                + s3 * (r3[j] as f32);
        }
        i += 4;
    }
    while i < k {
        let xi = x[i];
        if xi != 0.0 {
            let si = xi * scale[i];
            let row = &w[i * n..(i + 1) * n];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += si * (wv as f32);
            }
        }
        i += 1;
    }
}

/// int8 twin of [`gemm_add`]: `c += a @ dequant(b)` with `b` stored as
/// int8 and one f32 `scale` per k-row, folded into the broadcast scalar.
pub fn gemm_add_i8(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[i8],
    scale: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(scale.len(), k);
    debug_assert_eq!(c.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TILE_N).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j1];
                let mut kk = k0;
                while kk + 4 <= k1 {
                    let (x0, x1, x2, x3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                    let (s0, s1, s2, s3) = (
                        x0 * scale[kk],
                        x1 * scale[kk + 1],
                        x2 * scale[kk + 2],
                        x3 * scale[kk + 3],
                    );
                    let r0 = &b[kk * n + j0..kk * n + j1];
                    let r1 = &b[(kk + 1) * n + j0..(kk + 1) * n + j1];
                    let r2 = &b[(kk + 2) * n + j0..(kk + 2) * n + j1];
                    let r3 = &b[(kk + 3) * n + j0..(kk + 3) * n + j1];
                    for (j, o) in crow.iter_mut().enumerate() {
                        *o += s0 * (r0[j] as f32)
                            + s1 * (r1[j] as f32)
                            + s2 * (r2[j] as f32)
                            + s3 * (r3[j] as f32);
                    }
                    kk += 4;
                }
                while kk < k1 {
                    let xi = arow[kk];
                    if xi != 0.0 {
                        let si = xi * scale[kk];
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for (o, &bv) in crow.iter_mut().zip(brow) {
                            *o += si * (bv as f32);
                        }
                    }
                    kk += 1;
                }
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// int8 twin of [`nearest_code`]: nearest row (L2) among `s` int8 rows of
/// width `dk` with one f32 `scale` per row. Each element dequantizes as
/// `scale[c] * q` — the exact value the dequantized f32 codebook holds —
/// so distances and the strict-`<` argmin match
/// `nearest_code(x, dequantized, s, dk)` bit for bit.
pub fn nearest_code_i8(x: &[f32], codebook: &[i8], scale: &[f32], s: usize, dk: usize) -> usize {
    debug_assert_eq!(codebook.len(), s * dk);
    debug_assert_eq!(scale.len(), s);
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for c in 0..s {
        let row = &codebook[c * dk..(c + 1) * dk];
        let sc = scale[c];
        let mut d = 0.0f32;
        for (a, &b) in x.iter().zip(row) {
            let t = a - sc * (b as f32);
            d += t * t;
        }
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

/// Quantize `w.len() / n` rows of width `n` to int8 with one f32 scale per
/// row: `scale[i] = max_j |w[i,j]| / 127`, `q[i,j] = round(w[i,j] /
/// scale[i])` clamped to the symmetric range `[-127, 127]` (-128 is never
/// produced). An all-zero row gets scale 0 and all-zero codes. The pass is
/// deterministic, and stable on its own output: requantizing
/// `scale[i] * q[i,j]` reproduces the codes `q` exactly (the scale agrees
/// to within one f32 rounding step).
pub fn quantize_rows_i8(w: &[f32], n: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(n > 0 && w.len() % n == 0, "bad row width {n} for {} elements", w.len());
    let k = w.len() / n;
    // tvq-allow(zero_alloc): install-time quantization pass, runs once per
    // weight load — never on the per-token decode path
    let mut q = vec![0i8; w.len()];
    // tvq-allow(zero_alloc): same install-time pass as the line above
    let mut scale = vec![0.0f32; k];
    for i in 0..k {
        let row = &w[i * n..(i + 1) * n];
        let mut amax = 0.0f32;
        for &v in row {
            amax = amax.max(v.abs());
        }
        if amax == 0.0 {
            continue; // scale 0, codes 0: dequantizes to the exact zeros
        }
        let s = amax / 127.0;
        scale[i] = s;
        for (qv, &v) in q[i * n..(i + 1) * n].iter_mut().zip(row) {
            *qv = (v / s).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scale)
}

/// Dequantize int8 rows back to f32: `out[i,j] = scale[i] * q[i,j]`. This
/// single multiply is the canonical dequantized value — the same one the
/// int8 kernels reconstruct in-register.
pub fn dequantize_rows_i8(q: &[i8], scale: &[f32], n: usize) -> Vec<f32> {
    assert!(n > 0 && q.len() % n == 0, "bad row width {n} for {} elements", q.len());
    debug_assert_eq!(scale.len(), q.len() / n);
    // tvq-allow(zero_alloc): install-time/test helper; decode kernels
    // dequantize in-register instead of materializing rows
    q.iter().enumerate().map(|(ix, &v)| scale[ix / n] * (v as f32)).collect()
}

// ---------------------------------------------------------------------------
// f64 kernels (autodiff / training path)
// ---------------------------------------------------------------------------

/// f64 twin of [`dot`]: 4-way unrolled single pass, fixed accumulation
/// order, O(n).
#[inline]
pub fn dot64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    while i < a.len() {
        acc += a[i] * b[i];
        i += 1;
    }
    acc
}

/// f64 twin of [`matvec`]: `out = x @ w`, `w` row-major `[x.len(),
/// out.len()]`. Same axpy loop order (unrolled k outer, contiguous n
/// inner), O(k·n).
pub fn matvec64(w: &[f64], x: &[f64], out: &mut [f64]) {
    out.fill(0.0);
    matvec64_add(w, x, out);
}

/// f64 twin of [`matvec_add`]: `out += x @ w`.
pub fn matvec64_add(w: &[f64], x: &[f64], out: &mut [f64]) {
    let n = out.len();
    let k = x.len();
    debug_assert_eq!(w.len(), k * n);
    let mut i = 0;
    while i + 4 <= k {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
            i += 4;
            continue;
        }
        let r0 = &w[i * n..(i + 1) * n];
        let r1 = &w[(i + 1) * n..(i + 2) * n];
        let r2 = &w[(i + 2) * n..(i + 3) * n];
        let r3 = &w[(i + 3) * n..(i + 4) * n];
        for (j, o) in out.iter_mut().enumerate() {
            *o += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
        }
        i += 4;
    }
    while i < k {
        let xi = x[i];
        if xi != 0.0 {
            let row = &w[i * n..(i + 1) * n];
            for (o, &wv) in out.iter_mut().zip(row) {
                *o += xi * wv;
            }
        }
        i += 1;
    }
}

/// Transpose product for the reverse sweep: `out[i] = Σ_o w[i,o]·y[o]`
/// with `w` row-major `[out.len(), y.len()]`. Loop order: one [`dot64`]
/// per output element — each reads a contiguous row of `w`, so the walk is
/// storage-order and unit-stride. Complexity O(k·n). Overwrites `out`.
pub fn matvec64_t(w: &[f64], y: &[f64], out: &mut [f64]) {
    let o = y.len();
    debug_assert_eq!(w.len(), out.len() * o);
    for (i, acc) in out.iter_mut().enumerate() {
        *acc = dot64(&w[i * o..(i + 1) * o], y);
    }
}

/// Outer-product gradient accumulation: `g[i,o] += x[i]·y[o]`, `g`
/// row-major `[x.len(), y.len()]`. Loop order: rows of `g` outer (skipping
/// `x[i] == 0`, which embeddings/one-hots hit often), contiguous `o`
/// inner. Complexity O(k·n).
pub fn outer_acc64(g: &mut [f64], x: &[f64], y: &[f64]) {
    let o = y.len();
    debug_assert_eq!(g.len(), x.len() * o);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &mut g[i * o..(i + 1) * o];
        for (acc, &yv) in row.iter_mut().zip(y) {
            *acc += xi * yv;
        }
    }
}

// ---------------------------------------------------------------------------
// thread pool
// ---------------------------------------------------------------------------

/// Number of hardware threads (the `num_threads = 0` / auto default).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

pub(crate) fn effective_threads(num_threads: usize) -> usize {
    if num_threads == 0 {
        default_threads()
    } else {
        num_threads
    }
}

/// One submitted index space. The raw closure pointer is only dereferenced
/// after a *successful* claim (`next.fetch_add < n`): `parallel_for`
/// cannot return until `finished == n`, which requires all `n` successful
/// claims to have already happened — so a stale queue handle popped after
/// `parallel_for` returned always sees `next >= n` and never touches
/// `task`, and every dereference is strictly inside the closure's
/// lifetime.
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    finished: AtomicUsize,
    n: usize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    cv: Condvar,
}

// SAFETY: the raw `task` pointer is the only non-auto-Send field. Its
// pointee outlives every reader: `parallel_for` blocks on the completion
// barrier until all `n` items finish, and stale handles bail before
// dereferencing (see `run_to_exhaustion`), so moving a `Job` across
// threads never lets `task` dangle.
unsafe impl Send for Job {}
// SAFETY: shared `&Job` access is what the pool is built on — every field
// is an atomic, a `Mutex`/`Condvar`, or plain `usize`, and `task` points
// at a `dyn Fn(usize) + Sync` closure, so concurrent calls through it are
// sound by the pointee's own `Sync` bound.
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run items until the index space is exhausted. Called by
    /// the submitting thread and by any helper that popped this job.
    fn run_to_exhaustion(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                return;
            }
            // SAFETY: the claim above succeeded (i < n), so `parallel_for`
            // is still blocked on its completion barrier (it needs this
            // item's `finished` increment, which has not happened yet) and
            // the borrowed closure behind `task` is alive. Stale handles
            // popped later never reach this point — see the type docs.
            let f: &(dyn Fn(usize) + Sync) = unsafe { &*self.task };
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_err() {
                self.panicked.store(true, Ordering::Release);
            }
            // AcqRel chains every item's writes into the final increment,
            // so the waiter observes all of them after `done`
            if self.finished.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
                *self.done.lock().unwrap() = true;
                self.cv.notify_all();
            }
        }
    }
}

struct PoolState {
    queue: VecDeque<Arc<Job>>,
    workers: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    cv: Condvar,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { queue: VecDeque::new(), workers: 0 }),
        cv: Condvar::new(),
    })
}

fn worker_loop() {
    let p = pool();
    loop {
        let job = {
            let mut st = p.state.lock().unwrap();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    break j;
                }
                st = p.cv.wait(st).unwrap();
            }
        };
        // a stale handle (job already drained) exits immediately
        job.run_to_exhaustion();
    }
}

/// Run `f(0), f(1), …, f(n-1)` with up to `num_threads` lanes (0 = all
/// cores). The calling thread participates; `num_threads - 1` parked pool
/// workers help. Items are claimed atomically, each index runs exactly
/// once, and the call returns only after every item has finished (so `f`
/// may borrow from the caller's stack). Panics in items are re-raised
/// here after the barrier. With `num_threads <= 1` (or `n <= 1`) this is
/// a plain sequential loop on the caller — no pool, no atomics.
///
/// Nesting (an item calling back into the pool) cannot deadlock — the
/// inner caller always drains its own index space — but it mostly
/// serializes while busy workers hold the outer items, so the engine
/// parallelizes at one level per code path (see `DESIGN.md` §7).
pub fn parallel_for(num_threads: usize, n: usize, f: &(dyn Fn(usize) + Sync)) {
    let nt = effective_threads(num_threads).min(n);
    if nt <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let job = Arc::new(Job {
        task: f as *const (dyn Fn(usize) + Sync),
        next: AtomicUsize::new(0),
        finished: AtomicUsize::new(0),
        n,
        panicked: AtomicBool::new(false),
        done: Mutex::new(false),
        cv: Condvar::new(),
    });
    let helpers = nt - 1;
    {
        let p = pool();
        let mut st = p.state.lock().unwrap();
        while st.workers < helpers {
            st.workers += 1;
            std::thread::Builder::new()
                // tvq-allow(zero_alloc): one-time lazy worker spawn; the
                // steady-state contract holds at nt <= 1 where no worker
                // is ever created (pinned by zero_alloc_decode.rs)
                .name(format!("tvq-kernel-{}", st.workers))
                .spawn(worker_loop)
                .expect("spawn pool worker");
        }
        for _ in 0..helpers {
            st.queue.push_back(Arc::clone(&job));
        }
        p.cv.notify_all();
    }
    job.run_to_exhaustion();
    {
        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.cv.wait(done).unwrap();
        }
    }
    if job.panicked.load(Ordering::Acquire) {
        panic!("a parallel_for work item panicked");
    }
}

/// [`parallel_for`] over a slice of owned work items, giving each
/// invocation `&mut` access to exactly one element. This is the engine's
/// batch-lane entry point: build one item per lane (disjoint row views
/// into the state tensors), then let the pool claim lanes.
pub fn parallel_for_items<T, F>(num_threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    struct ItemsPtr<T>(*mut T);
    // SAFETY: each index is claimed exactly once by `parallel_for`, so no
    // two invocations alias the same element; T: Send moves the element
    // access to the claiming thread.
    unsafe impl<T: Send> Sync for ItemsPtr<T> {}
    let ptr = ItemsPtr(items.as_mut_ptr());
    let n = items.len();
    let run = |i: usize| {
        // SAFETY: i < n and each i is claimed exactly once (see ItemsPtr).
        let item = unsafe { &mut *ptr.0.add(i) };
        f(i, item);
    };
    parallel_for(num_threads, n, &run);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Reference triple loop in f64 (i → j → k, textbook order).
    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Property: blocked GEMM == naive triple loop over assorted shapes,
    /// including non-multiples of TILE_K/TILE_N and degenerate dims.
    #[test]
    fn gemm_matches_naive_triple_loop_assorted_shapes() {
        let mut rng = Rng::new(0xB10C);
        let shapes = [
            (1, 1, 1),
            (3, 5, 7),
            (4, TILE_K, TILE_N),
            (2, TILE_K + 3, TILE_N + 5),
            (5, TILE_K - 1, 2 * TILE_N + 1),
            (7, 2 * TILE_K + 9, 33),
            (16, 64, 96),
            (1, 130, 257),
        ];
        for &(m, k, n) in &shapes {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut c);
            let want = naive_gemm(m, k, n, &a, &b);
            for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                let tol = 1e-4 * (1.0 + w.abs());
                assert!(
                    (got as f64 - w).abs() < tol,
                    "gemm({m},{k},{n})[{i}] = {got} want {w}"
                );
            }
        }
    }

    #[test]
    fn gemm_add_accumulates() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (3, 10, 6);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c = vec![1.0f32; m * n];
        gemm_add(m, k, n, &a, &b, &mut c);
        let want = naive_gemm(m, k, n, &a, &b);
        for (&got, &w) in c.iter().zip(&want) {
            assert!((got as f64 - (w + 1.0)).abs() < 1e-4, "{got} vs {}", w + 1.0);
        }
    }

    #[test]
    fn matvec_matches_gemm_row() {
        let mut rng = Rng::new(9);
        for &(k, n) in &[(1usize, 1usize), (4, 7), (63, 65), (64, 128), (130, 31)] {
            let w = rand_vec(&mut rng, k * n);
            let x = rand_vec(&mut rng, k);
            let mut out = vec![0.0f32; n];
            matvec(&w, &x, &mut out);
            let want = naive_gemm(1, k, n, &x, &w);
            for (&got, &wv) in out.iter().zip(&want) {
                assert!((got as f64 - wv).abs() < 1e-4 * (1.0 + wv.abs()));
            }
            // the _add variant really accumulates
            matvec_add(&w, &x, &mut out);
            for (&got, &wv) in out.iter().zip(&want) {
                assert!((got as f64 - 2.0 * wv).abs() < 2e-4 * (1.0 + wv.abs()));
            }
        }
    }

    #[test]
    fn f64_kernels_match_references() {
        let mut rng = Rng::new(11);
        let (k, n) = (37, 29);
        let w: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f64; n];
        matvec64(&w, &x, &mut out);
        for j in 0..n {
            let want: f64 = (0..k).map(|i| x[i] * w[i * n + j]).sum();
            assert!((out[j] - want).abs() < 1e-10, "matvec64[{j}]");
        }
        let mut outt = vec![0.0f64; k];
        matvec64_t(&w, &y, &mut outt);
        for i in 0..k {
            let want: f64 = (0..n).map(|j| w[i * n + j] * y[j]).sum();
            assert!((outt[i] - want).abs() < 1e-10, "matvec64_t[{i}]");
        }
        let mut g = vec![0.5f64; k * n];
        outer_acc64(&mut g, &x, &y);
        for i in 0..k {
            for j in 0..n {
                let want = 0.5 + x[i] * y[j];
                assert!((g[i * n + j] - want).abs() < 1e-12, "outer_acc64[{i},{j}]");
            }
        }
        let d = dot64(&x, &w[..k]);
        let want: f64 = (0..k).map(|i| x[i] * w[i]).sum();
        assert!((d - want).abs() < 1e-10);
    }

    #[test]
    fn bf16_kernels_bit_match_f32_on_dequantized_weights() {
        use crate::tensor::f32_to_bf16;
        let mut rng = Rng::new(0xBF16);
        for &(m, k, n) in &[(1usize, 5usize, 9usize), (3, 64, 128), (4, 67, 131), (2, 130, 31)] {
            let wf = rand_vec(&mut rng, k * n);
            let wq: Vec<u16> = wf.iter().map(|&v| f32_to_bf16(v)).collect();
            let deq: Vec<f32> = wq.iter().map(|&v| bf16_to_f32(v)).collect();
            let x = rand_vec(&mut rng, k);
            let mut got = rand_vec(&mut rng, n);
            let mut want = got.clone();
            matvec_add_bf16(&wq, &x, &mut got);
            matvec_add(&deq, &x, &mut want);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "matvec_add_bf16({k},{n})"
            );
            let a = rand_vec(&mut rng, m * k);
            let mut cg = rand_vec(&mut rng, m * n);
            let mut cw = cg.clone();
            gemm_add_bf16(m, k, n, &a, &wq, &mut cg);
            gemm_add(m, k, n, &a, &deq, &mut cw);
            assert_eq!(
                cg.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                cw.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "gemm_add_bf16({m},{k},{n})"
            );
        }
    }

    #[test]
    fn i8_kernels_match_f32_on_dequantized_weights() {
        let mut rng = Rng::new(0x18);
        for &(m, k, n) in &[(1usize, 5usize, 9usize), (3, 64, 128), (4, 67, 131), (2, 130, 31)] {
            let wf = rand_vec(&mut rng, k * n);
            let (q, scale) = quantize_rows_i8(&wf, n);
            let deq = dequantize_rows_i8(&q, &scale, n);
            let x = rand_vec(&mut rng, k);
            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            matvec_add_i8(&q, &scale, &x, &mut got);
            matvec_add(&deq, &x, &mut want);
            // scale folding reassociates one multiply -> tolerance, not bits
            for (j, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g as f64 - w as f64).abs() <= 1e-5 * (1.0 + w.abs() as f64),
                    "matvec_add_i8({k},{n})[{j}]: {g} vs {w}"
                );
            }
            let a = rand_vec(&mut rng, m * k);
            let mut cg = vec![0.0f32; m * n];
            let mut cw = vec![0.0f32; m * n];
            gemm_add_i8(m, k, n, &a, &q, &scale, &mut cg);
            gemm_add(m, k, n, &a, &deq, &mut cw);
            for (j, (&g, &w)) in cg.iter().zip(&cw).enumerate() {
                assert!(
                    (g as f64 - w as f64).abs() <= 1e-5 * (1.0 + w.abs() as f64),
                    "gemm_add_i8({m},{k},{n})[{j}]: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn nearest_code_i8_exactly_matches_f32_scan_on_dequantized() {
        let mut rng = Rng::new(0x5CA1E);
        for _ in 0..50 {
            let s = 1 + (rng.next_u64() % 40) as usize;
            let dk = 1 + (rng.next_u64() % 33) as usize;
            let cb = rand_vec(&mut rng, s * dk);
            let (q, scale) = quantize_rows_i8(&cb, dk);
            let deq = dequantize_rows_i8(&q, &scale, dk);
            let x = rand_vec(&mut rng, dk);
            assert_eq!(
                nearest_code_i8(&x, &q, &scale, s, dk),
                nearest_code(&x, &deq, s, dk),
                "s={s} dk={dk}"
            );
        }
    }

    #[test]
    fn quantize_rows_i8_error_bound_and_stability() {
        let mut rng = Rng::new(0x1A8);
        let n = 37;
        let mut w = rand_vec(&mut rng, 5 * n);
        w[2 * n..3 * n].fill(0.0); // an all-zero row
        let (q, scale) = quantize_rows_i8(&w, n);
        assert_eq!(scale[2], 0.0);
        assert!(q[2 * n..3 * n].iter().all(|&v| v == 0));
        let deq = dequantize_rows_i8(&q, &scale, n);
        for (i, (&v, &d)) in w.iter().zip(&deq).enumerate() {
            let s = scale[i / n];
            // half a step plus the float rounding of the divide and the
            // dequant multiply (each ≤ 127·2^-24 steps)
            assert!((v - d).abs() <= s * 0.5001, "[{i}]: {v} vs {d} (scale {s})");
        }
        // requantizing the dequantized rows reproduces the codes exactly
        let (q2, scale2) = quantize_rows_i8(&deq, n);
        assert_eq!(q, q2);
        for (&a, &b) in scale.iter().zip(&scale2) {
            assert!((a - b).abs() <= a.abs() * 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn parallel_for_runs_every_index_exactly_once() {
        for nt in [1usize, 2, 4, 8] {
            for n in [0usize, 1, 2, 5, 17, 100] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                parallel_for(nt, n, &|i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} at nt={nt}, n={n}");
                }
            }
        }
    }

    #[test]
    fn parallel_for_items_gives_exclusive_mut_access() {
        let mut items: Vec<u64> = (0..50).collect();
        parallel_for_items(4, &mut items, |i, v| {
            *v = *v * 2 + i as u64;
        });
        for (i, &v) in items.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
