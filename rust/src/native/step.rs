//! Native step functions: the decode / train / eval entry points behind
//! [`crate::runtime::Executor::run`], each a pure function of its positional
//! inputs (validated upstream against the spec).
//!
//! The train step is the paper's full §3.4.2 recipe: one TBPTT window
//! forward through the complete model, exact reverse-mode gradients for
//! every parameter leaf (embedding, RMSNorms, multi-head VQ-attention
//! through the Theorem 3.7 block recurrence with straight-through through
//! the quantizer and the commit-loss term, gated FFN, readout — see
//! [`super::autodiff`]), a global-norm clip, and a bias-corrected Adam
//! update. Codebooks learn gradient-free via §3.4.1 EMA k-means; the `opt`
//! group carries both the EMA statistics and the Adam moments, so training
//! state round-trips through the step contract and checkpoint resume stays
//! bit-exact.
//!
//! Step functions receive pre-parsed weights ([`ParsedWeights`], cached by
//! identity inside [`super::NativeExecutor`]) so the per-step cost of
//! re-decoding the params group from raw bytes is paid once per distinct
//! weight set, not once per call.
//!
//! Every entry point takes the executor's [`super::NativeOptions`]
//! (thread budget, SIMD mode, decode batching). Decode and prefill run
//! **batched** by default — all active lanes advance through each layer
//! together via `model::forward_step_batched`, one GEMM per projection —
//! with a per-lane fallback (`batched_decode = false` /
//! `TVQ_BATCHED_DECODE=0`) that fans one whole row per pool work item.
//! The eval/train windows parallelize over batch lanes as before. Merges
//! happen in fixed row order and per-row kernel accumulation order never
//! depends on thread count, so outputs are bit-identical at any
//! `num_threads` within a fixed SIMD mode.

use anyhow::{bail, Result};

use std::sync::Arc;

use crate::tensor::HostTensor;

use super::autodiff::{
    flatten_params, train_forward_backward, unflatten_params, Carry64, ParamIx, QuantMode,
};
use super::kernels;
use super::layout::Layout;
use super::model::{
    forward_step_batched, forward_step_per_lane, forward_token_row, forward_token_row_opts,
    forward_window_dense, BatchScratch, Codebooks, LaneStep, Params, QuantParams, RowState,
    Scratch, State, TrainAccum,
};
use super::simd::Precision;
use super::NativeOptions;

/// Adam hyperparameters (§3.4.2; the schedule supplies the LR).
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;

/// Laplace smoothing for EMA codebook counts (van den Oord 2017).
const EMA_EPS: f32 = 1e-5;

/// Parsed params + codebooks — the executor's identity-keyed cache entry.
///
/// Under a reduced [`Precision`], `quant` holds the int8/bf16 weight twins
/// built once at parse time and `params`/`cb` hold the **dequantized**
/// mirrors (see [`QuantParams::build`]); under [`Precision::F32`], `quant`
/// is `None` and `params`/`cb` are the raw weights, bit-untouched.
pub(crate) struct ParsedWeights {
    pub params: Params,
    pub cb: Codebooks,
    pub quant: Option<QuantParams>,
}

/// Reusable decode scratch parked on the executor between calls — the
/// batched arena and/or the per-lane arenas, whichever the entry uses —
/// so steady-state serving through the executor surface re-allocates
/// neither (each is built lazily on first use and reused thereafter).
#[derive(Default)]
pub(crate) struct DecodeArena {
    pub batch: Option<BatchScratch>,
    pub lanes: Option<Vec<Scratch>>,
}

/// Number of leading input (and, for train, output) tensors that hold the
/// weights: the params group followed by the cb group.
pub(crate) fn weight_tensor_count(layout: &Layout) -> usize {
    let sp = SplitSpec::of(layout);
    sp.n_params + sp.n_cb
}

/// Parse the weight tensors of `inputs` into a cacheable [`ParsedWeights`],
/// quantizing the matmul weights once here (never on the hot path) when
/// `precision` is reduced.
pub(crate) fn parse_weights(
    layout: &Layout,
    inputs: &[HostTensor],
    precision: Precision,
) -> Result<ParsedWeights> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let mut params = Params::parse(cfg, &inputs[..sp.n_params])?;
    let mut cb = Codebooks::parse(cfg, &inputs[sp.n_params..sp.n_params + sp.n_cb])?;
    let quant = QuantParams::build(cfg, &mut params, &mut cb, precision);
    Ok(ParsedWeights { params, cb, quant })
}

struct SplitSpec {
    n_params: usize,
    n_cb: usize,
    n_opt: usize,
    n_state: usize,
}

impl SplitSpec {
    fn of(layout: &Layout) -> Self {
        let nl = layout.cfg.n_layers;
        Self {
            n_params: 10 * nl + 4,
            n_cb: nl,
            // per-layer (ema_count, ema_sum) + adam_m + adam_v + adam_t
            n_opt: 2 * nl + 3,
            n_state: 1 + 5 * nl,
        }
    }
}

/// `<preset>.decode`: (params, cb, state, token[B]) -> (state, logits[B,V]).
///
/// Batched by default: the B lanes move through each layer together so
/// every weight matrix streams once per step. The per-lane fallback runs
/// one whole row per pool work item. Both paths produce identical rows to
/// within last-ulp readout ordering (oracle-tested in `model`'s tests);
/// each path is bit-deterministic at any thread count.
pub(crate) fn run_decode(
    layout: &Layout,
    weights: &ParsedWeights,
    inputs: &[HostTensor],
    opts: &NativeOptions,
    arena: &mut DecodeArena,
) -> Result<Vec<HostTensor>> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let (b, v) = (cfg.batch_size, cfg.vocab_size);
    let st_base = sp.n_params + sp.n_cb;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;

    let mut logits = vec![0.0f32; b * v];
    if opts.batched_decode {
        let lanes: Vec<LaneStep> = (0..b)
            .map(|r| LaneStep { slot: r, token: tokens[r], want_logits: true })
            .collect();
        let bs = arena.batch.get_or_insert_with(|| BatchScratch::new(cfg));
        forward_step_batched(
            cfg,
            &weights.params,
            &weights.cb,
            weights.quant.as_ref(),
            &mut st,
            &lanes,
            &mut logits,
            bs,
            opts.num_threads,
            opts.simd,
        );
    } else {
        let scratch = arena
            .lanes
            .get_or_insert_with(|| (0..b).map(|_| Scratch::new(cfg)).collect());
        forward_step_per_lane(
            cfg,
            &weights.params,
            &weights.cb,
            weights.quant.as_ref(),
            &mut st,
            &tokens,
            &mut logits,
            scratch,
            opts.num_threads,
            opts.simd,
        );
    }
    let mut outputs = st.dump(layout, "state");
    outputs.push(HostTensor::from_f32(&[b, v], &logits));
    Ok(outputs)
}

/// `<preset>.prefill`: (params, cb, state, tokens[B, C], lens[B]) ->
/// (state, logits[B, V]) — the slot-session entry point.
///
/// Row `b` ingests `tokens[b, ..lens[b]]` through the same per-token
/// recurrence as decode, but computes logits only after its *last* token
/// (intermediate readouts are skipped — prompt ingestion discards them
/// anyway). Rows with `lens[b] == 0` are untouched: their state, including
/// `pos`, passes through bit-identically, which is what lets the engine
/// step only occupied lanes. Logits rows of inactive lanes are zero.
pub(crate) fn run_prefill(
    layout: &Layout,
    weights: &ParsedWeights,
    inputs: &[HostTensor],
    opts: &NativeOptions,
    arena: &mut DecodeArena,
) -> Result<Vec<HostTensor>> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let (b, v, c) = (cfg.batch_size, cfg.vocab_size, layout.prefill_chunk());
    let st_base = sp.n_params + sp.n_cb;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;
    let lens = inputs[st_base + sp.n_state + 1].as_i32()?;
    for (row, &len) in lens.iter().enumerate() {
        if len < 0 || len as usize > c {
            bail!("prefill: lens[{row}] = {len} outside 0..={c}");
        }
    }

    let mut logits = vec![0.0f32; b * v];
    if opts.batched_decode {
        // token-major: at step t every lane still ingesting advances one
        // token, all through shared GEMMs; a lane computes logits only at
        // its own last token. Per-row results are identical to the
        // lane-major order below because rows never interact.
        let max_len = lens.iter().map(|&l| l as usize).max().unwrap_or(0);
        let bs = arena.batch.get_or_insert_with(|| BatchScratch::new(cfg));
        let mut lanes: Vec<LaneStep> = Vec::with_capacity(b);
        for t in 0..max_len {
            lanes.clear();
            for row in 0..b {
                let len = lens[row] as usize;
                if t < len {
                    lanes.push(LaneStep {
                        slot: row,
                        token: tokens[row * c + t],
                        want_logits: t + 1 == len,
                    });
                }
            }
            forward_step_batched(
                cfg,
                &weights.params,
                &weights.cb,
                weights.quant.as_ref(),
                &mut st,
                &lanes,
                &mut logits,
                bs,
                opts.num_threads,
                opts.simd,
            );
        }
    } else {
        let scratch = arena
            .lanes
            .get_or_insert_with(|| (0..b).map(|_| Scratch::new(cfg)).collect());
        let mut work: Vec<(RowState<'_>, &mut [f32], &mut Scratch)> = st
            .rows()
            .into_iter()
            .zip(logits.chunks_mut(v).zip(scratch.iter_mut()))
            .map(|(rst, (out, sc))| (rst, out, sc))
            .collect();
        kernels::parallel_for_items(opts.num_threads, &mut work, |row, (rst, out, sc)| {
            let len = lens[row] as usize;
            let row_tokens = &tokens[row * c..row * c + len];
            for (i, &tok) in row_tokens.iter().enumerate() {
                let want = i + 1 == len;
                forward_token_row_opts(
                    cfg,
                    &weights.params,
                    &weights.cb,
                    weights.quant.as_ref(),
                    rst,
                    tok,
                    None,
                    want,
                    sc,
                    opts.simd,
                );
                if want {
                    out.copy_from_slice(&sc.logits);
                }
            }
        });
    }
    let mut outputs = st.dump(layout, "state");
    outputs.push(HostTensor::from_f32(&[b, v], &logits));
    Ok(outputs)
}

/// Run the f32 streaming forward over a [B, W+1] window, advancing `st`
/// (evaluation path; training uses the differentiable f64 twin in
/// [`super::autodiff`]). Returns per token (logits [V], target id), in
/// row-major order regardless of how rows were scheduled over threads.
fn forward_window(
    layout: &Layout,
    p: &Params,
    cb: &Codebooks,
    st: &mut State,
    tokens: &[i32],
    opts: &NativeOptions,
) -> Vec<(Vec<f32>, usize)> {
    let cfg = &layout.cfg;
    let (b, w, v) = (cfg.batch_size, cfg.window_len, cfg.vocab_size);
    let (nt, simd) = (opts.num_threads, opts.simd);
    let dense = cfg.attn_type == "full";
    // single-lane presets hand the whole thread budget to the dense window
    // kernels; multi-lane runs split the budget at the row level instead
    let inner_nt = if b > 1 { 1 } else { nt };
    let mut per_row: Vec<Vec<(Vec<f32>, usize)>> = (0..b).map(|_| Vec::new()).collect();
    {
        let mut work: Vec<_> = st.rows().into_iter().zip(per_row.iter_mut()).collect();
        kernels::parallel_for_items(nt, &mut work, |row, (rst, out)| {
            let row_tokens = &tokens[row * (w + 1)..(row + 1) * (w + 1)];
            let target = |t: usize| (row_tokens[t + 1].max(0) as usize).min(v - 1);
            if dense {
                // dense baseline: quadratic within the window, no carry memory
                **out = forward_window_dense(cfg, p, &row_tokens[..w], inner_nt, simd)
                    .into_iter()
                    .enumerate()
                    .map(|(t, (logits, _))| (logits, target(t)))
                    .collect();
                *rst.pos += w as i32;
            } else {
                let mut sc = Scratch::new(cfg);
                out.reserve(w);
                for t in 0..w {
                    forward_token_row(cfg, p, cb, None, rst, row_tokens[t], None, &mut sc, simd);
                    out.push((sc.logits.clone(), target(t)));
                }
            }
        });
    }
    per_row.into_iter().flatten().collect()
}

/// Average per-(layer,head) codebook usage perplexity exp(H(p)).
fn code_perplexity(layout: &Layout, accum: &TrainAccum) -> f64 {
    let cfg = &layout.cfg;
    let s = cfg.n_code;
    let mut total_ppl = 0.0f64;
    let mut n_groups = 0.0f64;
    for counts in &accum.code_counts {
        for hd in 0..cfg.n_heads {
            let slice = &counts[hd * s..(hd + 1) * s];
            let tot: f64 = slice.iter().sum();
            if tot <= 0.0 {
                continue;
            }
            let mut ent = 0.0f64;
            for &c in slice {
                if c > 0.0 {
                    let pr = c / tot;
                    ent -= pr * pr.ln();
                }
            }
            total_ppl += ent.exp();
            n_groups += 1.0;
        }
    }
    if n_groups > 0.0 {
        total_ppl / n_groups
    } else {
        0.0
    }
}

/// §3.4.1 EMA k-means codebook update from this window's assignments.
///
/// Builds the updated codebook directly from the EMA statistics — each
/// element is written exactly once (rewritten rows from `es / smoothed`,
/// untouched rows copied from `old_cb`) — instead of deep-cloning the full
/// codebook first and then overwriting nearly all of it, which is what
/// the previous `weights.cb.clone()` in the train step did every window.
fn ema_update(
    layout: &Layout,
    accum: &TrainAccum,
    old_cb: &Codebooks,
    ema_count: &mut [Vec<f32>],
    ema_sum: &mut [Vec<f32>],
) -> Codebooks {
    let cfg = &layout.cfg;
    let (s, dk) = (cfg.n_code, cfg.d_k);
    let gamma = cfg.ema_rate as f32;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let counts = &accum.code_counts[l];
        let sums = &accum.key_sums[l];
        let ec = &mut ema_count[l];
        let es = &mut ema_sum[l];
        let old = &old_cb.layers[l];
        for (e, &c) in ec.iter_mut().zip(counts) {
            *e = gamma * *e + (1.0 - gamma) * c as f32;
        }
        for (e, &ks) in es.iter_mut().zip(sums) {
            *e = gamma * *e + (1.0 - gamma) * ks as f32;
        }
        let mut cbl = vec![0.0f32; old.len()];
        for hd in 0..cfg.n_heads {
            let head = &ec[hd * s..(hd + 1) * s];
            let total: f32 = head.iter().sum();
            for c in 0..s {
                let base = (hd * s + c) * dk;
                let smoothed = if total > 0.0 {
                    (head[c] + EMA_EPS) / (total + s as f32 * EMA_EPS) * total
                } else {
                    0.0
                };
                if smoothed > 0.0 {
                    for d in 0..dk {
                        cbl[base + d] = es[base + d] / smoothed;
                    }
                } else {
                    cbl[base..base + dk].copy_from_slice(&old[base..base + dk]);
                }
            }
        }
        layers.push(Arc::new(cbl));
    }
    Codebooks { layers }
}

/// `<preset>.train`: one full §3.4.2 TBPTT update — backprop through the
/// whole model, global-norm clip, bias-corrected Adam at exactly the
/// schedule LR (the reported and applied LR are the same number), EMA
/// codebook learning.
///
/// (params, cb, opt, carry, tokens[B,W+1], lr, seed) ->
/// (params, cb, opt, carry, metrics[loss, ce, commit, grad_norm, code_ppl, lr]).
pub(crate) fn run_train(
    layout: &Layout,
    weights: &ParsedWeights,
    inputs: &[HostTensor],
    opts: &NativeOptions,
) -> Result<(Vec<HostTensor>, ParsedWeights)> {
    let nt = opts.num_threads;
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let opt_base = sp.n_params + sp.n_cb;
    let mut ema_count: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);
    let mut ema_sum: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        ema_count.push(inputs[opt_base + 2 * l].as_f32()?);
        ema_sum.push(inputs[opt_base + 2 * l + 1].as_f32()?);
    }
    let adam_base = opt_base + 2 * cfg.n_layers;
    let mut adam_m = inputs[adam_base].as_f32()?;
    let mut adam_v = inputs[adam_base + 1].as_f32()?;
    let adam_t_prev = *inputs[adam_base + 2]
        .as_i32()?
        .first()
        .ok_or_else(|| anyhow::anyhow!("empty adam_t tensor"))?;
    let st_base = opt_base + sp.n_opt;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;
    let lr = inputs[st_base + sp.n_state + 1].first_f32()?;

    // --- forward + exact reverse-mode gradients (f64) ---------------------
    let px = ParamIx::new(cfg);
    let mut flat = flatten_params(&weights.params);
    if adam_m.len() != flat.len() || adam_v.len() != flat.len() {
        bail!(
            "adam moment length {} / {} does not match param count {}",
            adam_m.len(),
            adam_v.len(),
            flat.len()
        );
    }
    let cb64: Vec<Vec<f64>> = weights
        .cb
        .layers
        .iter()
        .map(|l| l.iter().map(|&x| x as f64).collect())
        .collect();
    let mut carry = Carry64::from_state(&st);
    let out = train_forward_backward(
        cfg,
        &px,
        &flat,
        &cb64,
        &mut carry,
        &tokens,
        QuantMode::Nearest,
        nt,
    );
    carry.write_state(&mut st);

    // --- global-norm clip + Adam ------------------------------------------
    let mut sq = 0.0f64;
    for &g in &out.grads {
        sq += g * g;
    }
    let grad_norm = sq.sqrt();
    let clip = cfg.grad_clip;
    let clip_scale = if clip > 0.0 && grad_norm > clip { clip / grad_norm } else { 1.0 };
    let adam_t = adam_t_prev + 1;
    let bc1 = 1.0 - ADAM_B1.powi(adam_t);
    let bc2 = 1.0 - ADAM_B2.powi(adam_t);
    let lr64 = lr as f64;
    for i in 0..flat.len() {
        let g = out.grads[i] * clip_scale;
        let m = ADAM_B1 * adam_m[i] as f64 + (1.0 - ADAM_B1) * g;
        let v = ADAM_B2 * adam_v[i] as f64 + (1.0 - ADAM_B2) * g * g;
        adam_m[i] = m as f32;
        adam_v[i] = v as f32;
        flat[i] -= lr64 * (m / bc1) / ((v / bc2).sqrt() + ADAM_EPS);
    }
    let new_params = unflatten_params(&px, &flat);

    // --- EMA codebook learning (gradient-free, §3.4.1) --------------------
    let code_ppl = code_perplexity(layout, &out.accum);
    let new_cb = if cfg.attn_type != "full" {
        ema_update(layout, &out.accum, &weights.cb, &mut ema_count, &mut ema_sum)
    } else {
        // dense presets never rewrite codebooks: share the Arc'd layers
        weights.cb.clone()
    };

    let loss = out.ce + cfg.commit_coef * out.commit;
    let metrics = [
        loss as f32,
        out.ce as f32,
        out.commit as f32,
        grad_norm as f32,
        code_ppl as f32,
        lr,
    ];

    let mut outputs = new_params.dump(layout);
    outputs.extend(new_cb.dump(layout));
    let opt_leaves = layout.opt_leaves();
    for l in 0..cfg.n_layers {
        outputs.push(HostTensor::from_f32(&opt_leaves[2 * l].shape, &ema_count[l]));
        outputs.push(HostTensor::from_f32(&opt_leaves[2 * l + 1].shape, &ema_sum[l]));
    }
    outputs.push(HostTensor::from_f32(&[adam_m.len()], &adam_m));
    outputs.push(HostTensor::from_f32(&[adam_v.len()], &adam_v));
    outputs.push(HostTensor::from_i32(&[1], &[adam_t]));
    outputs.extend(st.dump(layout, "carry"));
    outputs.push(HostTensor::from_f32(&[6], &metrics));
    // training always produces f32 weights; a decode executor re-seeding
    // its cache from these re-quantizes at install time (`seed_cache`)
    Ok((outputs, ParsedWeights { params: new_params, cb: new_cb, quant: None }))
}

/// `<preset>.eval` / `tput-*` bench: forward-only over a window.
/// (params, cb, carry, tokens) -> (carry, metrics[total_ce_nats, n_tokens]).
pub(crate) fn run_eval(
    layout: &Layout,
    weights: &ParsedWeights,
    inputs: &[HostTensor],
    opts: &NativeOptions,
) -> Result<Vec<HostTensor>> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let st_base = sp.n_params + sp.n_cb;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;

    let steps = forward_window(layout, &weights.params, &weights.cb, &mut st, &tokens, opts);
    let mut total_ce = 0.0f64;
    for (logits, target) in &steps {
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let exps_sum: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum();
        let p_t = (((logits[*target] as f64) - m).exp() / exps_sum).max(1e-300);
        total_ce -= p_t.ln();
    }
    let mut outputs = st.dump(layout, "carry");
    outputs.push(HostTensor::from_f32(
        &[2],
        &[total_ce as f32, steps.len() as f32],
    ));
    Ok(outputs)
}

/// Dispatch on the spec entry; shared by [`super::NativeExecutor`].
/// `opts` carries the executor's runtime knobs (thread budget, SIMD mode,
/// decode batching — all fixed at executor init). Returns the step
/// outputs plus, for train, the freshly produced weights (so the executor
/// can re-seed its identity-keyed cache without re-parsing).
pub(crate) fn run_entry(
    entry: &str,
    layout: &Layout,
    weights: &ParsedWeights,
    inputs: &[HostTensor],
    opts: &NativeOptions,
    arena: &mut DecodeArena,
) -> Result<(Vec<HostTensor>, Option<ParsedWeights>)> {
    match entry {
        "decode" => Ok((run_decode(layout, weights, inputs, opts, arena)?, None)),
        "prefill" => Ok((run_prefill(layout, weights, inputs, opts, arena)?, None)),
        "train" => {
            let (outputs, new_weights) = run_train(layout, weights, inputs, opts)?;
            Ok((outputs, Some(new_weights)))
        }
        "eval" | "bench" => Ok((run_eval(layout, weights, inputs, opts)?, None)),
        other => bail!("native backend: unknown entry '{other}'"),
    }
}
