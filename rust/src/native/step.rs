//! Native step functions: the decode / train / eval entry points behind
//! [`crate::runtime::Executor::run`], each a pure function of its positional
//! inputs (validated upstream against the spec).
//!
//! Training is deliberately scoped (this is a serving-first engine): the
//! forward pass is the full multi-layer VQ-attention model, the codebooks
//! learn online via the paper's §3.4.1 EMA k-means (gradient-free), and
//! gradient descent trains the linear readout (`wout`/`bout`) on the
//! cross-entropy — a reservoir-style probe that gives honest, monotonically
//! improving loss curves without a full backprop engine. Full backprop
//! through the block recurrence is ROADMAP work; the step contract
//! (params/opt/cb/carry in, same + metrics out) already matches it.

use anyhow::{bail, Result};

use crate::tensor::HostTensor;

use super::layout::Layout;
use super::model::{
    forward_token, forward_window_dense, Codebooks, Params, State, TrainAccum,
};

/// The LR schedule targets the paper's full-model Adam recipe; plain SGD on
/// the linear readout needs a far larger step to move within a scaled-down
/// run, so the native trainer rescales it (documented in DESIGN.md; tuned so
/// a 30-step quickstart drops ~0.5 nats while 300-step runs stay stable
/// under the global-norm clip).
const READOUT_LR_SCALE: f32 = 5000.0;

/// Laplace smoothing for EMA codebook counts (van den Oord 2017).
const EMA_EPS: f32 = 1e-5;

struct SplitSpec {
    n_params: usize,
    n_cb: usize,
    n_opt: usize,
    n_state: usize,
}

impl SplitSpec {
    fn of(layout: &Layout) -> Self {
        let nl = layout.cfg.n_layers;
        Self {
            n_params: 10 * nl + 4,
            n_cb: nl,
            n_opt: 2 * nl,
            n_state: 1 + 5 * nl,
        }
    }
}

/// `<preset>.decode`: (params, cb, state, token[B]) -> (state, logits[B,V]).
pub(crate) fn run_decode(layout: &Layout, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let (b, v) = (cfg.batch_size, cfg.vocab_size);
    let p = Params::parse(cfg, &inputs[..sp.n_params])?;
    let cb = Codebooks::parse(cfg, &inputs[sp.n_params..sp.n_params + sp.n_cb])?;
    let st_base = sp.n_params + sp.n_cb;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;

    let mut logits = vec![0.0f32; b * v];
    for row in 0..b {
        let (row_logits, _) = forward_token(cfg, &p, &cb, &mut st, row, tokens[row], None);
        logits[row * v..(row + 1) * v].copy_from_slice(&row_logits);
    }
    let mut outputs = st.dump(layout, "state");
    outputs.push(HostTensor::from_f32(&[b, v], &logits));
    Ok(outputs)
}

/// Per-(token,row) forward results the readout trainer consumes.
struct WindowForward {
    /// Per token: (logits [V], y [dm], target id).
    steps: Vec<(Vec<f32>, Vec<f32>, usize)>,
    accum: TrainAccum,
}

/// Run the forward pass over a [B, W+1] token window, advancing `st`.
fn forward_window(
    layout: &Layout,
    p: &Params,
    cb: &Codebooks,
    st: &mut State,
    tokens: &[i32],
    with_accum: bool,
) -> WindowForward {
    let cfg = &layout.cfg;
    let (b, w, v) = (cfg.batch_size, cfg.window_len, cfg.vocab_size);
    let mut accum = TrainAccum::new(cfg);
    let mut steps = Vec::with_capacity(b * w);
    for row in 0..b {
        let row_tokens = &tokens[row * (w + 1)..(row + 1) * (w + 1)];
        if cfg.attn_type == "full" {
            // dense baseline: quadratic within the window, no carry memory
            for (t, (logits, y)) in
                forward_window_dense(cfg, p, &row_tokens[..w]).into_iter().enumerate()
            {
                let target = (row_tokens[t + 1].max(0) as usize).min(v - 1);
                steps.push((logits, y, target));
            }
            st.pos[row] += w as i32;
        } else {
            for t in 0..w {
                let acc = if with_accum { Some(&mut accum) } else { None };
                let (logits, y) = forward_token(cfg, p, cb, st, row, row_tokens[t], acc);
                let target = (row_tokens[t + 1].max(0) as usize).min(v - 1);
                steps.push((logits, y, target));
            }
        }
    }
    WindowForward { steps, accum }
}

/// Mean CE (nats/token) + mean readout gradients from forward results.
fn ce_and_readout_grads(
    steps: &[(Vec<f32>, Vec<f32>, usize)],
    dm: usize,
    v: usize,
) -> (f64, Vec<f64>, Vec<f64>) {
    let n = steps.len().max(1) as f64;
    let mut ce = 0.0f64;
    let mut grad_w = vec![0.0f64; dm * v];
    let mut grad_b = vec![0.0f64; v];
    for (logits, y, target) in steps {
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let exps: Vec<f64> = logits.iter().map(|&x| ((x as f64) - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        ce -= (exps[*target] / z).max(1e-300).ln();
        for (vix, &e) in exps.iter().enumerate() {
            let d = e / z - if vix == *target { 1.0 } else { 0.0 };
            grad_b[vix] += d;
            for (dix, &yd) in y.iter().enumerate() {
                grad_w[dix * v + vix] += yd as f64 * d;
            }
        }
    }
    ce /= n;
    for g in grad_w.iter_mut() {
        *g /= n;
    }
    for g in grad_b.iter_mut() {
        *g /= n;
    }
    (ce, grad_w, grad_b)
}

/// Average per-(layer,head) codebook usage perplexity exp(H(p)).
fn code_perplexity(layout: &Layout, accum: &TrainAccum) -> f64 {
    let cfg = &layout.cfg;
    let s = cfg.n_code;
    let mut total_ppl = 0.0f64;
    let mut n_groups = 0.0f64;
    for counts in &accum.code_counts {
        for hd in 0..cfg.n_heads {
            let slice = &counts[hd * s..(hd + 1) * s];
            let tot: f64 = slice.iter().sum();
            if tot <= 0.0 {
                continue;
            }
            let mut ent = 0.0f64;
            for &c in slice {
                if c > 0.0 {
                    let pr = c / tot;
                    ent -= pr * pr.ln();
                }
            }
            total_ppl += ent.exp();
            n_groups += 1.0;
        }
    }
    if n_groups > 0.0 {
        total_ppl / n_groups
    } else {
        0.0
    }
}

/// §3.4.1 EMA k-means codebook update from this window's assignments.
fn ema_update(
    layout: &Layout,
    accum: &TrainAccum,
    cb: &mut Codebooks,
    ema_count: &mut [Vec<f32>],
    ema_sum: &mut [Vec<f32>],
) {
    let cfg = &layout.cfg;
    let (s, dk) = (cfg.n_code, cfg.d_k);
    let gamma = cfg.ema_rate as f32;
    for l in 0..cfg.n_layers {
        let counts = &accum.code_counts[l];
        let sums = &accum.key_sums[l];
        let ec = &mut ema_count[l];
        let es = &mut ema_sum[l];
        let cbl = &mut cb.layers[l];
        for (e, &c) in ec.iter_mut().zip(counts) {
            *e = gamma * *e + (1.0 - gamma) * c as f32;
        }
        for (e, &ks) in es.iter_mut().zip(sums) {
            *e = gamma * *e + (1.0 - gamma) * ks as f32;
        }
        for hd in 0..cfg.n_heads {
            let head = &ec[hd * s..(hd + 1) * s];
            let total: f32 = head.iter().sum();
            if total <= 0.0 {
                continue;
            }
            for c in 0..s {
                let smoothed = (head[c] + EMA_EPS) / (total + s as f32 * EMA_EPS) * total;
                if smoothed <= 0.0 {
                    continue;
                }
                let base = (hd * s + c) * dk;
                for d in 0..dk {
                    cbl[base + d] = es[base + d] / smoothed;
                }
            }
        }
    }
}

/// `<preset>.train`: one §3.4.2 TBPTT update.
/// (params, cb, opt, carry, tokens[B,W+1], lr, seed) ->
/// (params, cb, opt, carry, metrics[loss, ce, commit, grad_norm, code_ppl, lr]).
pub(crate) fn run_train(layout: &Layout, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let (dm, v) = (cfg.d_model, cfg.vocab_size);
    let mut p = Params::parse(cfg, &inputs[..sp.n_params])?;
    let mut cb = Codebooks::parse(cfg, &inputs[sp.n_params..sp.n_params + sp.n_cb])?;
    let opt_base = sp.n_params + sp.n_cb;
    let mut ema_count: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);
    let mut ema_sum: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        ema_count.push(inputs[opt_base + 2 * l].as_f32()?);
        ema_sum.push(inputs[opt_base + 2 * l + 1].as_f32()?);
    }
    let st_base = opt_base + sp.n_opt;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;
    let lr = inputs[st_base + sp.n_state + 1].first_f32()?;

    let fwd = forward_window(layout, &p, &cb, &mut st, &tokens, true);
    let (ce, grad_w, grad_b) = ce_and_readout_grads(&fwd.steps, dm, v);

    // global-norm clip, then the rescaled SGD step on the readout
    let mut sq = 0.0f64;
    for &g in grad_w.iter().chain(&grad_b) {
        sq += g * g;
    }
    let grad_norm = sq.sqrt();
    let clip = cfg.grad_clip;
    let clip_scale = if clip > 0.0 && grad_norm > clip { clip / grad_norm } else { 1.0 };
    let step = (lr * READOUT_LR_SCALE) as f64 * clip_scale;
    for (w, &g) in p.wout.iter_mut().zip(&grad_w) {
        *w -= (step * g) as f32;
    }
    for (b_, &g) in p.bout.iter_mut().zip(&grad_b) {
        *b_ -= (step * g) as f32;
    }

    let commit = if fwd.accum.commit_n > 0.0 {
        fwd.accum.commit_sum / fwd.accum.commit_n
    } else {
        0.0
    };
    let code_ppl = code_perplexity(layout, &fwd.accum);
    if cfg.attn_type != "full" {
        ema_update(layout, &fwd.accum, &mut cb, &mut ema_count, &mut ema_sum);
    }

    let loss = ce + cfg.commit_coef * commit;
    let metrics = [
        loss as f32,
        ce as f32,
        commit as f32,
        grad_norm as f32,
        code_ppl as f32,
        lr,
    ];

    let mut outputs = p.dump(layout);
    outputs.extend(cb.dump(layout));
    let opt_leaves = layout.opt_leaves();
    for l in 0..cfg.n_layers {
        outputs.push(HostTensor::from_f32(&opt_leaves[2 * l].shape, &ema_count[l]));
        outputs.push(HostTensor::from_f32(&opt_leaves[2 * l + 1].shape, &ema_sum[l]));
    }
    outputs.extend(st.dump(layout, "carry"));
    outputs.push(HostTensor::from_f32(&[6], &metrics));
    Ok(outputs)
}

/// `<preset>.eval` / `tput-*` bench: forward-only over a window.
/// (params, cb, carry, tokens) -> (carry, metrics[total_ce_nats, n_tokens]).
pub(crate) fn run_eval(layout: &Layout, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let cfg = &layout.cfg;
    let sp = SplitSpec::of(layout);
    let p = Params::parse(cfg, &inputs[..sp.n_params])?;
    let cb = Codebooks::parse(cfg, &inputs[sp.n_params..sp.n_params + sp.n_cb])?;
    let st_base = sp.n_params + sp.n_cb;
    let mut st = State::parse(cfg, &inputs[st_base..st_base + sp.n_state])?;
    let tokens = inputs[st_base + sp.n_state].as_i32()?;

    let fwd = forward_window(layout, &p, &cb, &mut st, &tokens, false);
    let mut total_ce = 0.0f64;
    for (logits, _, target) in &fwd.steps {
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let exps_sum: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum();
        let p_t = (((logits[*target] as f64) - m).exp() / exps_sum).max(1e-300);
        total_ce -= p_t.ln();
    }
    let mut outputs = st.dump(layout, "carry");
    outputs.push(HostTensor::from_f32(
        &[2],
        &[total_ce as f32, fwd.steps.len() as f32],
    ));
    Ok(outputs)
}

/// Dispatch on the spec entry; shared by [`super::NativeExecutor`].
pub(crate) fn run_entry(
    entry: &str,
    layout: &Layout,
    inputs: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    match entry {
        "decode" => run_decode(layout, inputs),
        "train" => run_train(layout, inputs),
        "eval" | "bench" => run_eval(layout, inputs),
        other => bail!("native backend: unknown entry '{other}'"),
    }
}
