//! Nucleus (top-p) sampling (Holtzman et al. 2020) — the paper samples with
//! nucleus 0.8-1.0 (Appendix D).

use crate::rng::Rng;

use super::SampleParams;

/// Temperature-scaled softmax over raw logits.
///
/// Defensive about non-finite logits (a diverged model or a buggy backend
/// must degrade a sample, not crash the serving loop): NaN logits carry
/// zero probability, `+inf` logits split the whole mass, and if nothing
/// finite remains the distribution falls back to uniform.
pub fn softmax_with_temperature(logits: &[f32], temperature: f32) -> Vec<f64> {
    let t = temperature.max(1e-4) as f64;
    let clean: Vec<f64> = logits
        .iter()
        .map(|&l| if l.is_nan() { f64::NEG_INFINITY } else { l as f64 })
        .collect();
    let m = clean.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::INFINITY {
        let n_inf = clean.iter().filter(|&&x| x == f64::INFINITY).count() as f64;
        return clean
            .iter()
            .map(|&x| if x == f64::INFINITY { 1.0 / n_inf } else { 0.0 })
            .collect();
    }
    if m == f64::NEG_INFINITY {
        return vec![1.0 / logits.len().max(1) as f64; logits.len()];
    }
    let exps: Vec<f64> = clean.iter().map(|&l| ((l - m) / t).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Sample a token id from the smallest set of tokens whose cumulative
/// probability exceeds `top_p`, renormalized.
pub fn nucleus_sample(logits: &[f32], params: SampleParams, rng: &mut Rng) -> i32 {
    let probs = softmax_with_temperature(logits, params.temperature);
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    // total order: never panics, and any residual non-finite values sort last
    idx.sort_unstable_by(|&a, &b| probs[b].total_cmp(&probs[a]));
    let p = params.top_p.clamp(0.0, 1.0) as f64;
    let mut cum = 0.0;
    let mut cutoff = idx.len();
    for (rank, &i) in idx.iter().enumerate() {
        cum += probs[i];
        if cum >= p {
            cutoff = rank + 1;
            break;
        }
    }
    let nucleus = &idx[..cutoff.max(1)];
    let weights: Vec<f64> = nucleus.iter().map(|&i| probs[i]).collect();
    nucleus[rng.categorical(&weights)] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax_with_temperature(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn low_temperature_sharpens() {
        let hot = softmax_with_temperature(&[1.0, 2.0], 2.0);
        let cold = softmax_with_temperature(&[1.0, 2.0], 0.1);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn tiny_top_p_is_greedy() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0, 5.0, 1.0, -2.0];
        for _ in 0..50 {
            let params = SampleParams { temperature: 1.0, top_p: 1e-6 };
            assert_eq!(nucleus_sample(&logits, params, &mut rng), 1);
        }
    }

    #[test]
    fn top_p_one_covers_support() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0, 0.0, 0.0];
        let mut seen = [false; 3];
        for _ in 0..300 {
            let params = SampleParams { temperature: 1.0, top_p: 1.0 };
            seen[nucleus_sample(&logits, params, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn nan_logit_is_excluded_not_panicking() {
        let mut rng = Rng::new(7);
        let logits = vec![f32::NAN, 2.0, 1.0, f32::NAN];
        for _ in 0..100 {
            let params = SampleParams { temperature: 1.0, top_p: 0.95 };
            let s = nucleus_sample(&logits, params, &mut rng);
            assert!(s == 1 || s == 2, "sampled a NaN-logit token: {s}");
        }
    }

    #[test]
    fn all_nan_logits_fall_back_to_uniform() {
        let mut rng = Rng::new(8);
        let logits = vec![f32::NAN; 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            let params = SampleParams { temperature: 1.0, top_p: 1.0 };
            seen[nucleus_sample(&logits, params, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform fallback missed ids: {seen:?}");
    }

    #[test]
    fn inf_logit_takes_all_mass() {
        let mut rng = Rng::new(9);
        let logits = vec![0.0, f32::INFINITY, 1.0];
        for _ in 0..50 {
            let params = SampleParams { temperature: 1.0, top_p: 1.0 };
            assert_eq!(nucleus_sample(&logits, params, &mut rng), 1);
        }
    }

    #[test]
    fn respects_distribution_roughly() {
        let mut rng = Rng::new(2);
        let logits = vec![0.0f32, (9f32).ln()]; // p = [0.1, 0.9]
        let params = SampleParams { temperature: 1.0, top_p: 1.0 };
        let n = 5000;
        let ones = (0..n)
            .filter(|_| nucleus_sample(&logits, params, &mut rng) == 1)
            .count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.03, "frac {frac}");
    }
}
