//! Linear-time sampling runtime with slot sessions.
//!
//! Drives a `<preset>.decode` / `<preset>.prefill` executor pair (native or
//! PJRT, via the [`crate::runtime::Backend`] abstraction). The compressive
//! cache state lives in the "state" group of the bundle ([B, ...] tensors:
//! rolling 2L key/value window + per-shortcode running means, per layer), so
//! per-token cost is O(S + 2L) — generation is linear in sequence length,
//! unlike a quadratic-attention sampler whose KV cache grows with T.
//!
//! The serving coordinator treats the B batch rows as *slots* and talks to
//! them through the session API:
//! * [`Sampler::prefill`] — chunked multi-token prompt ingestion into one
//!   slot (logits computed only after the last token; other slots
//!   untouched),
//! * [`Sampler::decode_active`] — one decode step over exactly the
//!   occupied lanes,
//! * [`Sampler::step_lanes`] — the primitive under both: each lane ingests
//!   1..=[`Sampler::prefill_chunk`] tokens in a single executor call, so a
//!   prefilling slot advances a whole chunk while co-resident decoders
//!   advance one token, in the same step.
//!
//! On the native backend the prefill/decode entries additionally advance
//! all addressed lanes through each model layer *together* (batched-lane
//! decode: one GEMM per projection, weights streamed once per step — see
//! DESIGN.md §7), so packing co-resident lanes into one [`Sampler::step_lanes`]
//! call is not just fewer executor round-trips but higher arithmetic
//! intensity per step. Lane results are bit-independent of co-residents
//! either way.
//!
//! When the backend has no `.prefill` artifact (the PJRT path), the session
//! API transparently falls back to full-batch token-by-token
//! [`Sampler::step`] calls — same results for the addressed lanes, old cost
//! model.

mod nucleus;
mod prefix_cache;

pub use nucleus::{nucleus_sample, softmax_with_temperature};
pub use prefix_cache::PrefixCacheStats;

use anyhow::{bail, Result};

use crate::native::LaneSnapshot;
use crate::rng::Rng;
use crate::runtime::{Backend, Executor, StateBundle};
use crate::tensor::HostTensor;

use prefix_cache::PrefixCache;

pub struct Sampler {
    pub exe: Box<dyn Executor>,
    /// `<preset>.prefill` when the backend offers it (native always does);
    /// `None` falls back to token-by-token full-batch stepping.
    prefill_exe: Option<Box<dyn Executor>>,
    pub bundle: StateBundle,
    preset: String,
    /// Prompt-prefix cache over lane snapshots (`Some` when enabled via
    /// `TVQ_PREFIX_CACHE` or [`Sampler::enable_prefix_cache`]).
    prefix_cache: Option<PrefixCache>,
}

#[derive(Debug, Clone, Copy)]
pub struct SampleParams {
    pub temperature: f32,
    pub top_p: f32,
}

impl Default for SampleParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_p: 0.95 }
    }
}

/// One occupied lane's decode input: which slot, which token to feed.
#[derive(Debug, Clone, Copy)]
pub struct SlotToken {
    pub slot: usize,
    pub token: i32,
}

/// One lane of a session step: `slot` ingests `tokens`
/// (1..=[`Sampler::prefill_chunk`] of them); logits come back for the last
/// token only.
#[derive(Debug, Clone)]
pub struct LaneInput {
    pub slot: usize,
    pub tokens: Vec<i32>,
}

impl Sampler {
    /// Load `<preset>.decode` (and `<preset>.prefill` if the backend has
    /// it) from any backend and initialize the shared state (params and
    /// codebooks from the backend, decode state zeroed).
    pub fn new(backend: &dyn Backend, preset: &str) -> Result<Self> {
        let exe = backend.load(&format!("{preset}.decode"))?;
        let prefill_exe = backend.load(&format!("{preset}.prefill")).ok();
        let mut bundle = StateBundle::zeros_for(exe.spec());
        bundle.set_named(backend.init_state(preset)?);
        // TVQ_PREFIX_CACHE=<capacity> enables the prompt-prefix cache
        // (0/unset = off); the CLI relays --prefix-cache N here
        let prefix_cache = std::env::var("TVQ_PREFIX_CACHE")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .map(PrefixCache::new);
        Ok(Self { exe, prefill_exe, bundle, preset: preset.to_string(), prefix_cache })
    }

    /// Overwrite model weights from a training checkpoint (TVQ with params/cb
    /// groups, e.g. saved by train::save_checkpoint). Invalidates the
    /// prefix cache: snapshots taken under the old weights are not valid
    /// prefix states for the new model.
    pub fn load_weights(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut staged = StateBundle::new();
        staged.load_groups(path)?;
        self.install_weights(&staged)
    }

    /// Overwrite model weights from an already-parsed bundle (params/cb
    /// groups). Tensor payloads are `Arc`-backed, so N fleet replicas can
    /// parse a checkpoint once and install shared clones — per-replica cost
    /// is refcounts, not copies. Invalidates the prefix cache like
    /// [`Sampler::load_weights`].
    pub fn install_weights(&mut self, staged: &StateBundle) -> Result<()> {
        for g in ["params", "cb"] {
            let ts = staged.group(g)?.to_vec();
            self.bundle.set_group(g, ts);
        }
        if let Some(cache) = self.prefix_cache.as_mut() {
            cache.clear();
        }
        Ok(())
    }

    /// Turn the prompt-prefix cache on with room for `capacity` prompts
    /// (replacing any existing cache). See [`prefix_cache`][mod] docs.
    ///
    /// [mod]: self::PrefixCacheStats
    pub fn enable_prefix_cache(&mut self, capacity: usize) {
        self.prefix_cache = Some(PrefixCache::new(capacity));
    }

    /// Hit/miss/eviction counters of the prefix cache, `None` when off.
    pub fn prefix_cache_stats(&self) -> Option<PrefixCacheStats> {
        self.prefix_cache.as_ref().map(|c| c.stats())
    }

    pub fn batch_size(&self) -> usize {
        self.exe.spec().config.batch_size
    }

    pub fn vocab_size(&self) -> usize {
        self.exe.spec().config.vocab_size
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    /// Max tokens one lane can ingest per [`Sampler::step_lanes`] call: the
    /// chunk width `C` of the prefill artifact's `tokens[B, C]` input, or 1
    /// on the token-by-token fallback path.
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_exe
            .as_ref()
            .and_then(|e| {
                e.spec()
                    .input_group("tokens")
                    .first()
                    .and_then(|(_, l)| l.shape.get(1).copied())
            })
            .unwrap_or(1)
    }

    /// Feed one token per batch row; returns logits [B, V] row-major.
    ///
    /// This is the lockstep full-batch primitive (every row advances,
    /// logits for every row). Serving paths prefer the session API below,
    /// which skips idle lanes and intermediate readouts.
    pub fn step(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        if tokens.len() != b {
            bail!("step: {} tokens for batch size {b}", tokens.len());
        }
        self.bundle
            .set_group("token", vec![HostTensor::from_i32(&[b], tokens)]);
        let inputs = self.bundle.assemble(self.exe.spec())?;
        let outputs = self.exe.run(&inputs)?;
        self.bundle.absorb(self.exe.spec(), outputs)?;
        let logits = self.bundle.group("logits")?[0].as_f32()?;
        let v = self.vocab_size();
        Ok((0..b).map(|i| logits[i * v..(i + 1) * v].to_vec()).collect())
    }

    /// One session step: every lane ingests its tokens (a prefill chunk or
    /// a single decode token), and logits come back per lane for its last
    /// token. Lanes not listed are untouched on the native path. Returns
    /// one logits row per input lane, in input order.
    pub fn step_lanes(&mut self, lanes: &[LaneInput]) -> Result<Vec<Vec<f32>>> {
        if lanes.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.batch_size();
        let c = self.prefill_chunk();
        let mut seen = vec![false; b];
        for lane in lanes {
            if lane.slot >= b {
                bail!("step_lanes: slot {} out of range (batch {b})", lane.slot);
            }
            if seen[lane.slot] {
                bail!("step_lanes: slot {} appears twice", lane.slot);
            }
            seen[lane.slot] = true;
            if lane.tokens.is_empty() || lane.tokens.len() > c {
                bail!(
                    "step_lanes: lane for slot {} has {} tokens (want 1..={c})",
                    lane.slot,
                    lane.tokens.len()
                );
            }
        }
        if self.prefill_exe.is_some() {
            self.step_lanes_native(lanes)
        } else {
            self.step_lanes_fallback(lanes)
        }
    }

    fn step_lanes_native(&mut self, lanes: &[LaneInput]) -> Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        let v = self.vocab_size();
        let c = self.prefill_chunk();
        let mut toks = vec![0i32; b * c];
        let mut lens = vec![0i32; b];
        for lane in lanes {
            toks[lane.slot * c..lane.slot * c + lane.tokens.len()]
                .copy_from_slice(&lane.tokens);
            lens[lane.slot] = lane.tokens.len() as i32;
        }
        self.bundle
            .set_group("tokens", vec![HostTensor::from_i32(&[b, c], &toks)]);
        self.bundle
            .set_group("lens", vec![HostTensor::from_i32(&[b], &lens)]);
        let exe = self
            .prefill_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("step_lanes_native without a prefill artifact"))?;
        let inputs = self.bundle.assemble(exe.spec())?;
        let outputs = exe.run(&inputs)?;
        self.bundle.absorb(exe.spec(), outputs)?;
        let logits = self.bundle.group("logits")?[0].as_f32()?;
        Ok(lanes
            .iter()
            .map(|l| logits[l.slot * v..(l.slot + 1) * v].to_vec())
            .collect())
    }

    /// No prefill artifact: emulate lanes with full-batch token steps. This
    /// advances *every* row's state (idle rows are fed token 0), matching
    /// the pre-session engine's cost model; serving resets a slot on
    /// admission, so the garbage in unoccupied rows is never observed.
    fn step_lanes_fallback(&mut self, lanes: &[LaneInput]) -> Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        let max_len = lanes.iter().map(|l| l.tokens.len()).max().unwrap_or(0);
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); lanes.len()];
        for t in 0..max_len {
            let mut tokens = vec![0i32; b];
            for lane in lanes {
                if t < lane.tokens.len() {
                    tokens[lane.slot] = lane.tokens[t];
                }
            }
            let logits = self.step(&tokens)?;
            for (o, lane) in out.iter_mut().zip(lanes) {
                if t + 1 == lane.tokens.len() {
                    *o = logits[lane.slot].clone();
                }
            }
        }
        Ok(out)
    }

    /// Chunked prompt ingestion into one slot: feeds `tokens` through the
    /// recurrence [`Sampler::prefill_chunk`] tokens per executor call and
    /// returns the logits after the last one — the distribution the first
    /// generated token samples from. Other slots are untouched (native
    /// path). Cost is O(P) state updates but only O(P / C) executor
    /// round-trips and a single readout.
    pub fn prefill(&mut self, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("prefill: empty prompt for slot {slot}");
        }
        let c = self.prefill_chunk().max(1);
        let mut logits = Vec::new();
        for chunk in tokens.chunks(c) {
            logits = self
                .step_lanes(&[LaneInput { slot, tokens: chunk.to_vec() }])?
                .pop()
                .ok_or_else(|| anyhow::anyhow!("step_lanes: one lane in, no logits row out"))?;
        }
        Ok(logits)
    }

    /// One decode step over exactly the occupied lanes: feeds each
    /// `(slot, token)` and returns logits per lane, in input order.
    /// Unlisted slots are untouched (native path) — no logits are computed
    /// or discarded for empty lanes.
    pub fn decode_active(&mut self, active: &[SlotToken]) -> Result<Vec<Vec<f32>>> {
        let lanes: Vec<LaneInput> = active
            .iter()
            .map(|st| LaneInput { slot: st.slot, tokens: vec![st.token] })
            .collect();
        self.step_lanes(&lanes)
    }

    /// Zero the decode state of every slot.
    pub fn reset_all(&mut self) {
        let zeros: Vec<HostTensor> = self
            .exe
            .spec()
            .input_group("state")
            .iter()
            .map(|(_, l)| HostTensor::zeros(l.dtype, &l.shape))
            .collect();
        self.bundle.set_group("state", zeros);
    }

    /// Zero one batch row's decode state (continuous batching: a finished
    /// request frees its slot for a new sequence). Every "state" leaf is
    /// [B, ...], so slot `b`'s slice is a contiguous byte range.
    pub fn reset_slot(&mut self, slot: usize) -> Result<()> {
        let b = self.batch_size();
        if slot >= b {
            bail!("slot {slot} out of range (batch {b})");
        }
        let group = self
            .bundle
            .group_mut("state")
            .ok_or_else(|| anyhow::anyhow!("no state group"))?;
        for t in group.iter_mut() {
            if t.shape.first() != Some(&b) {
                bail!("state leaf not batched: {:?}", t.shape);
            }
            let stride = t.data.len() / b;
            t.data[slot * stride..(slot + 1) * stride].fill(0);
        }
        Ok(())
    }

    /// Capture one slot's decode state as a [`LaneSnapshot`] (fixed-size
    /// regardless of how many tokens the slot has consumed — Thm 3.7).
    /// Encode with [`LaneSnapshot::encode`] for storage or migration.
    pub fn snapshot_slot(&self, slot: usize) -> Result<LaneSnapshot> {
        let cfg = &self.exe.spec().config;
        let tensors = self.bundle.group("state")?;
        LaneSnapshot::from_tensors(cfg, tensors, slot)
    }

    /// Overwrite one slot's decode state from a snapshot, byte-exactly:
    /// the restored slot continues bit-identically to the snapshotted run
    /// (same backend, same SIMD × precision axis). Other slots untouched.
    pub fn restore_slot(&mut self, slot: usize, snap: &LaneSnapshot) -> Result<()> {
        let cfg = self.exe.spec().config.clone();
        let group = self
            .bundle
            .group_mut("state")
            .ok_or_else(|| anyhow::anyhow!("no state group"))?;
        snap.apply_to_tensors(&cfg, group, slot)
    }

    /// [`Sampler::snapshot_slot`] flattened to the checksummed snapshot
    /// wire format — the unit a fleet router hands from one replica to
    /// another during live migration.
    pub fn encode_slot(&self, slot: usize) -> Result<Vec<u8>> {
        let snap = self.snapshot_slot(slot)?;
        snap.encode(&self.exe.spec().config)
    }

    /// Decode + [`Sampler::restore_slot`] in one step: seat wire bytes from
    /// [`Sampler::encode_slot`] (possibly produced by another replica with
    /// the same preset) into `slot`, byte-exactly.
    pub fn restore_slot_wire(&mut self, slot: usize, bytes: &[u8]) -> Result<()> {
        let cfg = self.exe.spec().config.clone();
        let snap = LaneSnapshot::decode(&cfg, bytes)?;
        self.restore_slot(slot, &snap)
    }

    /// Copy slot `src`'s decode state over slot `dst` (beam fan-out:
    /// prefill a prompt once, fork it into N divergent sampling lanes).
    pub fn fork_slot(&mut self, src: usize, dst: usize) -> Result<()> {
        let b = self.batch_size();
        if src >= b || dst >= b {
            bail!("fork_slot: {src} -> {dst} out of range (batch {b})");
        }
        if src == dst {
            return Ok(());
        }
        let group = self
            .bundle
            .group_mut("state")
            .ok_or_else(|| anyhow::anyhow!("no state group"))?;
        for t in group.iter_mut() {
            if t.shape.first() != Some(&b) {
                bail!("state leaf not batched: {:?}", t.shape);
            }
            let stride = t.data.len() / b;
            t.data.copy_within(src * stride..(src + 1) * stride, dst * stride);
        }
        Ok(())
    }

    /// Prefix-cache lookup + restore: finds the longest cached prompt that
    /// prefixes `prompt`, restores its snapshot into `slot`, and returns
    /// `(matched_tokens, stored_logits)` — logits are `Some` only on an
    /// exact match (prefill can be skipped entirely). `Ok(None)` when the
    /// cache is off or nothing matches; the slot is untouched then.
    pub fn prefix_lookup(
        &mut self,
        slot: usize,
        prompt: &[i32],
    ) -> Result<Option<(usize, Option<Vec<f32>>)>> {
        let Some(cache) = self.prefix_cache.as_mut() else {
            return Ok(None);
        };
        let Some(hit) = cache.lookup(prompt) else {
            return Ok(None);
        };
        self.restore_slot(slot, &hit.snap)?;
        Ok(Some((hit.matched, hit.logits)))
    }

    /// Store `slot`'s current state (which must hold exactly the prefilled
    /// `prompt`) plus the last-token `logits` in the prefix cache. No-op
    /// when the cache is off.
    pub fn prefix_insert(&mut self, prompt: &[i32], slot: usize, logits: &[f32]) -> Result<()> {
        if self.prefix_cache.is_none() || prompt.is_empty() {
            return Ok(());
        }
        let snap = self.snapshot_slot(slot)?;
        if let Some(cache) = self.prefix_cache.as_mut() {
            cache.insert(prompt, snap, logits.to_vec());
        }
        Ok(())
    }

    /// Convenience: generate `n_tokens` continuations for a batch of
    /// prompts (all slots used). Prompts are ingested via chunked prefill
    /// (all rows in flight at once, each with its own prompt), then all
    /// rows decode together; on backends without a prefill artifact the
    /// old token-by-token teacher-forcing loop runs instead. Returns
    /// per-row generated token ids.
    pub fn generate(
        &mut self,
        prompts: &[Vec<i32>],
        n_tokens: usize,
        params: SampleParams,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        if prompts.len() != b {
            bail!("generate: {} prompts for batch size {b}", prompts.len());
        }
        self.reset_all();
        if self.prefill_exe.is_none() {
            return self.generate_stepwise(prompts, n_tokens, params, rng);
        }
        let prompts: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| if p.is_empty() { vec![0] } else { p.clone() })
            .collect();

        // phase 1: chunked prefill, every row in flight with its own prompt
        let c = self.prefill_chunk();
        let mut logits: Vec<Vec<f32>> = vec![Vec::new(); b];
        let mut pos = vec![0usize; b];
        // prefix cache: restore the longest cached prefix per row so the
        // loop below prefills only the suffix (nothing at all on an exact
        // match, whose stored logits seed the first sample directly)
        if self.prefix_cache.is_some() {
            for row in 0..b {
                if let Some((matched, l)) = self.prefix_lookup(row, &prompts[row])? {
                    match l {
                        Some(l) if !l.is_empty() => {
                            pos[row] = matched;
                            logits[row] = l;
                        }
                        _ if matched < prompts[row].len() => pos[row] = matched,
                        // exact match but unusable stored logits: the
                        // restored state has already consumed the last
                        // token, so fall back to a cold prefill
                        _ => self.reset_slot(row)?,
                    }
                }
            }
        }
        loop {
            let mut lanes = Vec::new();
            for (row, p) in prompts.iter().enumerate() {
                if pos[row] < p.len() {
                    let k = (p.len() - pos[row]).min(c);
                    lanes.push(LaneInput {
                        slot: row,
                        tokens: p[pos[row]..pos[row] + k].to_vec(),
                    });
                }
            }
            if lanes.is_empty() {
                break;
            }
            let lane_logits = self.step_lanes(&lanes)?;
            for (lane, l) in lanes.iter().zip(lane_logits) {
                pos[lane.slot] += lane.tokens.len();
                if pos[lane.slot] == prompts[lane.slot].len() {
                    logits[lane.slot] = l;
                }
            }
        }
        // cache the fully prefilled prompts (snapshot is O(model), so this
        // is cheap relative to the prefill it saves next time)
        if self.prefix_cache.is_some() {
            for row in 0..b {
                let l = logits[row].clone();
                self.prefix_insert(&prompts[row], row, &l)?;
            }
        }

        // phase 2: batched decode, sampling rows in fixed row order per step
        let mut outputs: Vec<Vec<i32>> = vec![Vec::with_capacity(n_tokens); b];
        for t in 0..n_tokens {
            let mut active = Vec::with_capacity(b);
            for (row, out) in outputs.iter_mut().enumerate() {
                let tok = nucleus_sample(&logits[row], params, rng);
                out.push(tok);
                active.push(SlotToken { slot: row, token: tok });
            }
            if t + 1 < n_tokens {
                logits = self.decode_active(&active)?;
            }
        }
        Ok(outputs)
    }

    /// Pre-session generate: teacher-force prompts one token per full-batch
    /// step (the only option without a prefill artifact).
    fn generate_stepwise(
        &mut self,
        prompts: &[Vec<i32>],
        n_tokens: usize,
        params: SampleParams,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        let max_prompt = prompts.iter().map(Vec::len).max().unwrap_or(0).max(1);
        let mut outputs = vec![Vec::with_capacity(n_tokens); b];
        let mut current: Vec<i32> = prompts
            .iter()
            .map(|p| p.first().copied().unwrap_or(0))
            .collect();
        let total = max_prompt + n_tokens - 1;
        for t in 0..total {
            let logits = self.step(&current)?;
            for row in 0..b {
                let next_in_prompt = prompts[row].get(t + 1).copied();
                current[row] = match next_in_prompt {
                    Some(tok) => tok, // still teacher-forcing this row
                    None => {
                        let tok = nucleus_sample(&logits[row], params, rng);
                        if outputs[row].len() < n_tokens {
                            outputs[row].push(tok);
                        }
                        tok
                    }
                };
            }
        }
        Ok(outputs)
    }

    /// Beam fan-out sampling: prefill `prompt` once into slot 0, fork the
    /// prefilled state into `n_beams` lanes ([`Sampler::fork_slot`] —
    /// O(model) per fork, Thm 3.7), then decode all beams together with
    /// per-beam rng streams derived from `seed`. With a near-greedy
    /// `params` every beam is bit-identical; with sampling they diverge
    /// from the first token while sharing the prompt's prefill cost.
    /// Returns one generated-token sequence per beam.
    pub fn generate_beams(
        &mut self,
        prompt: &[i32],
        n_beams: usize,
        n_tokens: usize,
        params: SampleParams,
        seed: u64,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        if n_beams == 0 || n_beams > b {
            bail!("generate_beams: {n_beams} beams for batch size {b}");
        }
        if self.prefill_exe.is_none() {
            bail!("generate_beams needs a prefill artifact (lane forking)");
        }
        self.reset_all();
        let prompt: Vec<i32> = if prompt.is_empty() { vec![0] } else { prompt.to_vec() };
        let logits0 = self.prefill(0, &prompt)?;
        for dst in 1..n_beams {
            self.fork_slot(0, dst)?;
        }
        let mut root = Rng::new(seed);
        let mut rngs: Vec<Rng> = (0..n_beams).map(|i| root.fork(i as u64)).collect();
        let mut logits: Vec<Vec<f32>> = vec![logits0; n_beams];
        let mut outputs: Vec<Vec<i32>> = vec![Vec::with_capacity(n_tokens); n_beams];
        for t in 0..n_tokens {
            let mut active = Vec::with_capacity(n_beams);
            for (beam, out) in outputs.iter_mut().enumerate() {
                let tok = nucleus_sample(&logits[beam], params, &mut rngs[beam]);
                out.push(tok);
                active.push(SlotToken { slot: beam, token: tok });
            }
            if t + 1 < n_tokens {
                logits = self.decode_active(&active)?;
            }
        }
        Ok(outputs)
    }
}
