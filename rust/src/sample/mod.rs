//! Linear-time sampling runtime.
//!
//! Drives a `<preset>.decode` executor (native or PJRT, via the
//! [`crate::runtime::Backend`] abstraction) token by token. The compressive
//! cache state lives in the "state" group of the bundle ([B, ...] tensors:
//! rolling 2L key/value window + per-shortcode running means, per layer), so
//! per-token cost is O(S + 2L) — generation is linear in sequence length,
//! unlike a quadratic-attention sampler whose KV cache grows with T.
//!
//! The sampler exposes per-slot control (reset/zero one batch row) so the
//! serving coordinator can run continuous batching on top of it.

mod nucleus;

pub use nucleus::{nucleus_sample, softmax_with_temperature};

use anyhow::{bail, Result};

use crate::rng::Rng;
use crate::runtime::{Backend, Executor, StateBundle};
use crate::tensor::HostTensor;

pub struct Sampler {
    pub exe: Box<dyn Executor>,
    pub bundle: StateBundle,
    preset: String,
}

#[derive(Debug, Clone, Copy)]
pub struct SampleParams {
    pub temperature: f32,
    pub top_p: f32,
}

impl Default for SampleParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_p: 0.95 }
    }
}

impl Sampler {
    /// Load `<preset>.decode` from any backend and initialize its state
    /// (params/codebooks from the backend, decode state zeroed).
    pub fn new(backend: &dyn Backend, preset: &str) -> Result<Self> {
        let exe = backend.load(&format!("{preset}.decode"))?;
        let mut bundle = StateBundle::zeros_for(exe.spec());
        bundle.set_named(backend.init_state(preset)?);
        Ok(Self { exe, bundle, preset: preset.to_string() })
    }

    /// Overwrite model weights from a training checkpoint (TVQ with params/cb
    /// groups, e.g. saved by train::save_checkpoint).
    pub fn load_weights(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut staged = StateBundle::new();
        staged.load_groups(path)?;
        for g in ["params", "cb"] {
            let ts = staged.group(g)?.to_vec();
            self.bundle.set_group(g, ts);
        }
        Ok(())
    }

    pub fn batch_size(&self) -> usize {
        self.exe.spec().config.batch_size
    }

    pub fn vocab_size(&self) -> usize {
        self.exe.spec().config.vocab_size
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    /// Feed one token per batch row; returns logits [B, V] row-major.
    pub fn step(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let b = self.batch_size();
        if tokens.len() != b {
            bail!("step: {} tokens for batch size {b}", tokens.len());
        }
        self.bundle
            .set_group("token", vec![HostTensor::from_i32(&[b], tokens)]);
        let inputs = self.bundle.assemble(self.exe.spec())?;
        let outputs = self.exe.run(&inputs)?;
        self.bundle.absorb(self.exe.spec(), outputs)?;
        let logits = self.bundle.group("logits")?[0].as_f32()?;
        let v = self.vocab_size();
        Ok((0..b).map(|i| logits[i * v..(i + 1) * v].to_vec()).collect())
    }

    /// Zero the decode state of every slot.
    pub fn reset_all(&mut self) {
        let zeros: Vec<HostTensor> = self
            .exe
            .spec()
            .input_group("state")
            .iter()
            .map(|(_, l)| HostTensor::zeros(l.dtype, &l.shape))
            .collect();
        self.bundle.set_group("state", zeros);
    }

    /// Zero one batch row's decode state (continuous batching: a finished
    /// request frees its slot for a new sequence). Every "state" leaf is
    /// [B, ...], so slot `b`'s slice is a contiguous byte range.
    pub fn reset_slot(&mut self, slot: usize) -> Result<()> {
        let b = self.batch_size();
        if slot >= b {
            bail!("slot {slot} out of range (batch {b})");
        }
        let group = self
            .bundle
            .group_mut("state")
            .ok_or_else(|| anyhow::anyhow!("no state group"))?;
        for t in group.iter_mut() {
            if t.shape.first() != Some(&b) {
                bail!("state leaf not batched: {:?}", t.shape);
            }
            let stride = t.data.len() / b;
            t.data[slot * stride..(slot + 1) * stride].fill(0);
        }
        Ok(())
    }

    /// Convenience: generate `n_tokens` continuations for a batch of prompts
    /// (all slots used; prompts teacher-forced token by token). Returns
    /// per-row generated token ids.
    pub fn generate(
        &mut self,
        prompts: &[Vec<i32>],
        n_tokens: usize,
        params: SampleParams,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch_size();
        if prompts.len() != b {
            bail!("generate: {} prompts for batch size {b}", prompts.len());
        }
        self.reset_all();
        let max_prompt = prompts.iter().map(Vec::len).max().unwrap_or(0).max(1);
        let mut outputs = vec![Vec::with_capacity(n_tokens); b];
        let mut current: Vec<i32> = prompts
            .iter()
            .map(|p| p.first().copied().unwrap_or(0))
            .collect();
        let total = max_prompt + n_tokens - 1;
        for t in 0..total {
            let logits = self.step(&current)?;
            for row in 0..b {
                let next_in_prompt = prompts[row].get(t + 1).copied();
                current[row] = match next_in_prompt {
                    Some(tok) => tok, // still teacher-forcing this row
                    None => {
                        let tok = nucleus_sample(&logits[row], params, rng);
                        if outputs[row].len() < n_tokens {
                            outputs[row].push(tok);
                        }
                        tok
                    }
                };
            }
        }
        Ok(outputs)
    }
}
