//! Prompt-prefix cache over lane snapshots (DESIGN.md §10).
//!
//! Transformer-VQ's fixed-size decode state makes prefix caching O(model)
//! per entry instead of O(prompt): after a prompt is prefilled, the lane's
//! [`LaneSnapshot`] *is* the prompt's entire attention state. A later
//! request whose prompt starts with a cached prompt restores the snapshot
//! and prefills only the suffix; an exact match also reuses the stored
//! last-token logits and skips prefill entirely. Restore is byte-exact,
//! so a cache hit is bit-identical to a cold prefill (pinned by
//! `rust/tests/snapshot_oracle.rs`).
//!
//! Entries are keyed by an FNV-1a-64 hash of the prompt token bytes (fast
//! exact-match reject) with the full token sequence stored alongside —
//! equality and prefix tests always compare tokens, so hash collisions
//! can never serve the wrong state. Eviction is LRU under a fixed
//! capacity; `Sampler::load_weights` clears the cache (a snapshot taken
//! under old weights is not a valid prefix state for the new model).
//! Enable via `TVQ_PREFIX_CACHE=<capacity>` / `--prefix-cache N` or
//! `Sampler::enable_prefix_cache` (off by default).

use crate::native::LaneSnapshot;

/// Counters exposed by `Sampler::prefix_cache_stats` (all monotonic
/// except nothing — cleared only with the cache itself).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Exact-prompt hits (prefill skipped entirely).
    pub hits: u64,
    /// Proper-prefix hits (only the suffix was prefilled).
    pub partial_hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Prompt tokens served from snapshots instead of prefill.
    pub hit_tokens: u64,
    /// Entries stored (refreshes of an existing prompt included).
    pub insertions: u64,
    /// Entries dropped by LRU pressure.
    pub evictions: u64,
}

/// A successful lookup: the snapshot to restore, how many prompt tokens
/// it covers, and — for exact matches — the stored last-token logits.
pub(crate) struct PrefixHit {
    pub snap: LaneSnapshot,
    pub matched: usize,
    pub logits: Option<Vec<f32>>,
}

struct Entry {
    hash: u64,
    prompt: Vec<i32>,
    snap: LaneSnapshot,
    logits: Vec<f32>,
    last_used: u64,
}

/// LRU map from prompt token sequences to prefilled lane snapshots.
pub(crate) struct PrefixCache {
    cap: usize,
    tick: u64,
    entries: Vec<Entry>,
    stats: PrefixCacheStats,
}

/// FNV-1a-64 over the little-endian bytes of the token ids.
fn prompt_hash(prompt: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in prompt {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

impl PrefixCache {
    pub fn new(capacity: usize) -> Self {
        Self { cap: capacity.max(1), tick: 0, entries: Vec::new(), stats: PrefixCacheStats::default() }
    }

    pub fn stats(&self) -> PrefixCacheStats {
        self.stats
    }

    /// Drop every entry (weights changed: cached states are stale).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Longest cached prompt that is a prefix of `prompt`; bumps its LRU
    /// stamp and the hit/miss counters.
    pub fn lookup(&mut self, prompt: &[i32]) -> Option<PrefixHit> {
        let h = prompt_hash(prompt);
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let exact = e.hash == h && e.prompt == prompt;
            let is_prefix = exact
                || (e.prompt.len() < prompt.len() && prompt[..e.prompt.len()] == e.prompt[..]);
            if is_prefix && best.is_none_or(|b| e.prompt.len() > self.entries[b].prompt.len()) {
                best = Some(i);
            }
        }
        let Some(i) = best else {
            self.stats.misses += 1;
            return None;
        };
        self.tick += 1;
        let e = &mut self.entries[i];
        e.last_used = self.tick;
        let full = e.prompt.len() == prompt.len();
        if full {
            self.stats.hits += 1;
        } else {
            self.stats.partial_hits += 1;
        }
        self.stats.hit_tokens += e.prompt.len() as u64;
        Some(PrefixHit {
            snap: e.snap.clone(),
            matched: e.prompt.len(),
            logits: if full { Some(e.logits.clone()) } else { None },
        })
    }

    /// Store (or refresh) the snapshot + last-token logits for `prompt`,
    /// evicting the least-recently-used entry at capacity.
    pub fn insert(&mut self, prompt: &[i32], snap: LaneSnapshot, logits: Vec<f32>) {
        if prompt.is_empty() {
            return;
        }
        self.tick += 1;
        let h = prompt_hash(prompt);
        if let Some(e) = self.entries.iter_mut().find(|e| e.hash == h && e.prompt == prompt) {
            e.snap = snap;
            e.logits = logits;
            e.last_used = self.tick;
            self.stats.insertions += 1;
            return;
        }
        if self.entries.len() >= self.cap {
            if let Some(ix) = (0..self.entries.len()).min_by_key(|&i| self.entries[i].last_used) {
                self.entries.swap_remove(ix);
                self.stats.evictions += 1;
            }
        }
        self.entries.push(Entry {
            hash: h,
            prompt: prompt.to_vec(),
            snap,
            logits,
            last_used: self.tick,
        });
        self.stats.insertions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(pos: i32) -> LaneSnapshot {
        LaneSnapshot {
            pos,
            layers: Vec::new(),
            rng: None,
            utf8_pending: Vec::new(),
            stop_tail: Vec::new(),
        }
    }

    #[test]
    fn exact_and_prefix_lookups() {
        let mut c = PrefixCache::new(4);
        c.insert(&[1, 2, 3], snap(3), vec![0.5]);
        c.insert(&[1, 2], snap(2), vec![0.25]);
        // exact: longest match is the full prompt, logits returned
        let hit = c.lookup(&[1, 2, 3]).unwrap();
        assert_eq!((hit.matched, hit.snap.pos), (3, 3));
        assert_eq!(hit.logits.as_deref(), Some(&[0.5][..]));
        // proper prefix: longest cached prefix wins, no logits
        let hit = c.lookup(&[1, 2, 3, 4]).unwrap();
        assert_eq!((hit.matched, hit.snap.pos), (3, 3));
        assert!(hit.logits.is_none());
        // shorter entry serves prompts the longer one can't
        let hit = c.lookup(&[1, 2, 9]).unwrap();
        assert_eq!((hit.matched, hit.snap.pos), (2, 2));
        assert!(c.lookup(&[9, 9]).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.partial_hits, s.misses, s.hit_tokens), (1, 2, 1, 8));
    }

    #[test]
    fn lru_eviction_under_capacity() {
        let mut c = PrefixCache::new(2);
        c.insert(&[1], snap(1), vec![]);
        c.insert(&[2], snap(1), vec![]);
        assert!(c.lookup(&[1]).is_some()); // touch [1] so [2] is LRU
        c.insert(&[3], snap(1), vec![]);
        assert!(c.lookup(&[2]).is_none(), "LRU entry must be evicted");
        assert!(c.lookup(&[1]).is_some() && c.lookup(&[3]).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn clear_drops_everything() {
        let mut c = PrefixCache::new(2);
        c.insert(&[1, 2], snap(2), vec![]);
        c.clear();
        assert!(c.lookup(&[1, 2]).is_none());
    }
}
