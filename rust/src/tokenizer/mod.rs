//! Tokenizers: byte-level (enwik8/ImageNet64 tracks) and a from-scratch BPE
//! trainer/encoder (PG-19 track; the paper used a SentencePiece BPE-32k
//! vocabulary — we train a scaled-down BPE on the synthetic book corpus).

pub mod bpe;

pub use bpe::Bpe;

/// Common interface over tokenizers.
pub trait Tokenizer {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &[u8]) -> Vec<u16>;
    fn decode(&self, tokens: &[u16]) -> Vec<u8>;
}

/// Identity byte tokenizer (vocab 256).
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        256
    }

    fn encode(&self, text: &[u8]) -> Vec<u16> {
        text.iter().map(|&b| b as u16).collect()
    }

    fn decode(&self, tokens: &[u16]) -> Vec<u8> {
        tokens.iter().map(|&t| (t & 0xFF) as u8).collect()
    }
}

/// Incremental UTF-8 decoder for byte-token streams (the serving path's
/// `delta` frames): push bytes as they are sampled, get back the maximal
/// decodable prefix each time. Incomplete multi-byte sequences are held
/// (at most 3 bytes) until their continuation arrives; invalid bytes
/// become U+FFFD immediately. By construction, the concatenation of every
/// emitted chunk plus [`Utf8Stream::flush`] is exactly the text of the
/// whole stream — so streamed deltas concatenate to the final text.
#[derive(Debug, Default)]
pub struct Utf8Stream {
    pending: Vec<u8>,
}

impl Utf8Stream {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one byte; returns whatever became decodable ("" while waiting
    /// on a multi-byte sequence).
    pub fn push(&mut self, byte: u8) -> String {
        self.push_bytes(&[byte])
    }

    pub fn push_bytes(&mut self, bytes: &[u8]) -> String {
        self.pending.extend_from_slice(bytes);
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.pending) {
                Ok(s) => {
                    out.push_str(s);
                    self.pending.clear();
                    return out;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    // `valid_up_to` bounds a well-formed prefix, so the
                    // lossy pass is exact here — and it cannot panic
                    out.push_str(&String::from_utf8_lossy(&self.pending[..valid]));
                    match e.error_len() {
                        // invalid sequence of known length: replace and continue
                        Some(bad) => {
                            out.push('\u{FFFD}');
                            self.pending.drain(..valid + bad);
                        }
                        // incomplete tail: hold it for the next push
                        None => {
                            self.pending.drain(..valid);
                            return out;
                        }
                    }
                }
            }
        }
    }

    /// End of stream: decode whatever is still held (an incomplete tail
    /// becomes replacement characters, like `from_utf8_lossy`).
    pub fn flush(&mut self) -> String {
        let s = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        s
    }

    /// The undecoded tail currently held (≤ 3 bytes of an incomplete
    /// multi-byte sequence) — captured by lane snapshots so a migrated
    /// stream emits exactly the same deltas as the unmigrated one.
    pub fn pending(&self) -> &[u8] {
        &self.pending
    }

    /// Rebuild a stream holding `pending` undecoded bytes (the inverse of
    /// [`Utf8Stream::pending`], for snapshot restore).
    pub fn from_pending(pending: &[u8]) -> Self {
        Self { pending: pending.to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utf8_stream_ascii_passthrough() {
        let mut s = Utf8Stream::new();
        let mut out = String::new();
        for b in b"hello" {
            out.push_str(&s.push(*b));
        }
        out.push_str(&s.flush());
        assert_eq!(out, "hello");
    }

    #[test]
    fn utf8_stream_reassembles_multibyte() {
        let mut s = Utf8Stream::new();
        let text = "héllo 🎉é";
        let mut out = String::new();
        let mut chunk_lens = Vec::new();
        for b in text.as_bytes() {
            let c = s.push(*b);
            chunk_lens.push(c.len());
            out.push_str(&c);
        }
        out.push_str(&s.flush());
        assert_eq!(out, text);
        // multi-byte sequences emit nothing until their last byte
        assert!(chunk_lens.contains(&0));
    }

    #[test]
    fn utf8_stream_replaces_invalid_and_incomplete() {
        let mut s = Utf8Stream::new();
        let mut out = String::new();
        out.push_str(&s.push_bytes(&[0x61, 0xFF, 0x62])); // a, invalid, b
        assert_eq!(out, "a\u{FFFD}b");
        // dangling lead byte flushes to a replacement char
        assert_eq!(s.push(0xC3), "");
        assert_eq!(s.flush(), "\u{FFFD}");
        // flush is idempotent once drained
        assert_eq!(s.flush(), "");
    }

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let text = b"hello \xffworld".to_vec();
        assert_eq!(t.decode(&t.encode(&text)), text);
        assert_eq!(t.vocab_size(), 256);
    }
}
