//! Tokenizers: byte-level (enwik8/ImageNet64 tracks) and a from-scratch BPE
//! trainer/encoder (PG-19 track; the paper used a SentencePiece BPE-32k
//! vocabulary — we train a scaled-down BPE on the synthetic book corpus).

pub mod bpe;

pub use bpe::Bpe;

/// Common interface over tokenizers.
pub trait Tokenizer {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &[u8]) -> Vec<u16>;
    fn decode(&self, tokens: &[u16]) -> Vec<u8>;
}

/// Identity byte tokenizer (vocab 256).
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        256
    }

    fn encode(&self, text: &[u8]) -> Vec<u16> {
        text.iter().map(|&b| b as u16).collect()
    }

    fn decode(&self, tokens: &[u16]) -> Vec<u8> {
        tokens.iter().map(|&t| (t & 0xFF) as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let text = b"hello \xffworld".to_vec();
        assert_eq!(t.decode(&t.encode(&text)), text);
        assert_eq!(t.vocab_size(), 256);
    }
}
