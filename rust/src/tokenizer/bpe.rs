//! Byte-pair encoding, from scratch (Sennrich-style, byte base vocabulary).
//!
//! Training: repeatedly merge the most frequent adjacent token pair into a
//! new symbol until the target vocabulary size is reached. Encoding applies
//! merges in training order (lowest rank first), the standard BPE greedy
//! scheme. Deterministic: frequency ties break on the lexicographically
//! smaller pair.

use std::collections::HashMap;

use super::Tokenizer;

#[derive(Debug, Clone)]
pub struct Bpe {
    /// merges[i] = (left, right) produced new symbol 256 + i.
    pub merges: Vec<(u16, u16)>,
    /// rank lookup: pair -> merge index.
    ranks: HashMap<(u16, u16), usize>,
    /// decoded byte expansion of every symbol.
    expansions: Vec<Vec<u8>>,
}

impl Bpe {
    /// Train on `corpus` until `vocab_size` symbols exist (>= 256).
    pub fn train(corpus: &[u8], vocab_size: usize) -> Self {
        assert!(vocab_size >= 256, "vocab must include all bytes");
        let mut tokens: Vec<u16> = corpus.iter().map(|&b| b as u16).collect();
        let mut merges = Vec::with_capacity(vocab_size - 256);
        while 256 + merges.len() < vocab_size {
            let mut counts: HashMap<(u16, u16), usize> = HashMap::new();
            for w in tokens.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&best, &n)) = counts
                .iter()
                .max_by(|a, b| a.1.cmp(b.1).then_with(|| b.0.cmp(a.0)))
            else {
                break;
            };
            if n < 2 {
                break; // nothing worth merging
            }
            let new_sym = (256 + merges.len()) as u16;
            merges.push(best);
            tokens = merge_pair(&tokens, best, new_sym);
        }
        Self::from_merges(merges)
    }

    pub fn from_merges(merges: Vec<(u16, u16)>) -> Self {
        let ranks = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i))
            .collect();
        let mut expansions: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        for &(l, r) in &merges {
            let mut e = expansions[l as usize].clone();
            e.extend_from_slice(&expansions[r as usize]);
            expansions.push(e);
        }
        Self { merges, ranks, expansions }
    }

    /// Save as a line-oriented text file: "left right" per merge, in rank
    /// order (the format is trivially diffable and versionable).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let mut out = String::from("# tvq-bpe v1\n");
        for (l, r) in &self.merges {
            out.push_str(&format!("{l} {r}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut merges = Vec::new();
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut it = line.split_whitespace();
            let l: u16 = it.next().ok_or_else(|| anyhow::anyhow!("bad merge line"))?.parse()?;
            let r: u16 = it.next().ok_or_else(|| anyhow::anyhow!("bad merge line"))?.parse()?;
            merges.push((l, r));
        }
        Ok(Self::from_merges(merges))
    }
}

fn merge_pair(tokens: &[u16], pair: (u16, u16), new_sym: u16) -> Vec<u16> {
    let mut out = Vec::with_capacity(tokens.len());
    let mut i = 0;
    while i < tokens.len() {
        if i + 1 < tokens.len() && (tokens[i], tokens[i + 1]) == pair {
            out.push(new_sym);
            i += 2;
        } else {
            out.push(tokens[i]);
            i += 1;
        }
    }
    out
}

impl Tokenizer for Bpe {
    fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    fn encode(&self, text: &[u8]) -> Vec<u16> {
        let mut tokens: Vec<u16> = text.iter().map(|&b| b as u16).collect();
        // repeatedly apply the lowest-rank applicable merge
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for (pos, w) in tokens.windows(2).enumerate() {
                if let Some(&rank) = self.ranks.get(&(w[0], w[1])) {
                    match best {
                        Some((r, _)) if r <= rank => {}
                        _ => best = Some((rank, pos)),
                    }
                }
            }
            match best {
                None => break,
                Some((rank, _)) => {
                    let pair = self.merges[rank];
                    tokens = merge_pair(&tokens, pair, (256 + rank) as u16);
                }
            }
        }
        tokens
    }

    fn decode(&self, tokens: &[u16]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in tokens {
            match self.expansions.get(t as usize) {
                Some(e) => out.extend_from_slice(e),
                None => out.push(b'?'),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trains_and_roundtrips() {
        let corpus = b"the cat sat on the mat. the cat sat again. the cat!";
        let bpe = Bpe::train(corpus, 280);
        assert!(bpe.vocab_size() > 256);
        let enc = bpe.encode(corpus);
        assert!(enc.len() < corpus.len(), "BPE should compress");
        assert_eq!(bpe.decode(&enc), corpus.to_vec());
    }

    #[test]
    fn roundtrips_unseen_bytes() {
        let bpe = Bpe::train(b"aaabbbaaabbb", 260);
        let text = b"zzz \xF0\x9F\x8E\x89 qqq";
        assert_eq!(bpe.decode(&bpe.encode(text)), text.to_vec());
    }

    #[test]
    fn most_frequent_pair_merged_first() {
        // "ab" appears 4x, others less
        let bpe = Bpe::train(b"abxabyabzab", 257);
        assert_eq!(bpe.merges[0], (b'a' as u16, b'b' as u16));
    }

    #[test]
    fn deterministic_training() {
        let c = b"some repeated text some repeated text some repeated";
        let a = Bpe::train(c, 270);
        let b = Bpe::train(c, 270);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn save_load_preserves_encoding() {
        let corpus = b"hello hello hello world world";
        let bpe = Bpe::train(corpus, 264);
        let dir = crate::testutil::TempDir::new();
        let p = dir.join("bpe.txt");
        bpe.save(&p).unwrap();
        let bpe2 = Bpe::load(&p).unwrap();
        assert_eq!(bpe.encode(corpus), bpe2.encode(corpus));
        assert_eq!(bpe2.decode(&bpe2.encode(corpus)), corpus.to_vec());
    }

    #[test]
    fn stops_when_no_repeats() {
        let bpe = Bpe::train(b"abcdefg", 300);
        assert!(bpe.vocab_size() < 300);
    }
}
