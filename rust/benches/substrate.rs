//! `cargo bench --bench substrate` — micro-benchmarks of the L3 substrates
//! that sit near the hot paths: corpus generation, TBPTT batching, BPE,
//! TVQ (de)serialization, nucleus sampling, and the rust VQ-attention
//! reference (the analytic quadratic-cost model).

use transformer_vq::bench::{Bencher, Table};
use transformer_vq::data::{build_corpus, markov, TbpttBatcher};
use transformer_vq::rng::Rng;
use transformer_vq::sample::{nucleus_sample, SampleParams};
use transformer_vq::store::{read_tvq, write_tvq};
use transformer_vq::tensor::HostTensor;
use transformer_vq::testutil::TempDir;
use transformer_vq::tokenizer::{Bpe, Tokenizer};
use transformer_vq::vqref;

fn main() {
    let b = Bencher { warmup_iters: 1, min_iters: 5, max_iters: 2000,
                      budget: std::time::Duration::from_secs(2) };
    let mut table = Table::new(&["bench", "mean", "throughput"]);

    // corpus generation
    let stats = b.run("markov corpus 1MB", || {
        std::hint::black_box(markov::generate(1_000_000, 1));
    });
    table.row(vec!["markov gen 1MB".into(), format!("{:.2?}", stats.mean),
                   format!("{:.1} MB/s", 1.0 / stats.mean_secs())]);

    // TBPTT batching
    let corpus = build_corpus("markov", 2_000_000, 0).unwrap();
    let mut batcher = TbpttBatcher::new(corpus.tokens.clone(), 8, 128).unwrap();
    let stats = b.run("tbptt next_batch", || {
        std::hint::black_box(batcher.next_batch());
    });
    table.row(vec!["tbptt batch (8x129)".into(), format!("{:.2?}", stats.mean),
                   format!("{:.2} Mtok/s",
                           8.0 * 129.0 / stats.mean_secs() / 1e6)]);

    // BPE encode
    let text: Vec<u8> = corpus.tokens.iter().take(200_000).map(|&t| t as u8).collect();
    let bpe = Bpe::train(&text[..20_000], 512);
    let chunk = &text[..4096];
    let stats = b.run("bpe encode 4KB", || {
        std::hint::black_box(bpe.encode(chunk));
    });
    table.row(vec!["bpe encode 4KB".into(), format!("{:.2?}", stats.mean),
                   format!("{:.2} MB/s", 4096.0 / stats.mean_secs() / 1e6)]);

    // TVQ store
    let dir = TempDir::new();
    let vals: Vec<f32> = (0..1_000_000).map(|i| i as f32).collect();
    let tensors = vec![("big".to_string(), HostTensor::from_f32(&[1000, 1000], &vals))];
    let p = dir.join("bench.tvq");
    let stats = b.run("tvq write 4MB", || {
        write_tvq(&p, &tensors).unwrap();
    });
    table.row(vec!["tvq write 4MB".into(), format!("{:.2?}", stats.mean),
                   format!("{:.0} MB/s", 4.0 / stats.mean_secs())]);
    let stats = b.run("tvq read 4MB", || {
        std::hint::black_box(read_tvq(&p).unwrap());
    });
    table.row(vec!["tvq read 4MB".into(), format!("{:.2?}", stats.mean),
                   format!("{:.0} MB/s", 4.0 / stats.mean_secs())]);

    // nucleus sampling over a byte vocabulary
    let mut rng = Rng::new(0);
    let logits: Vec<f32> = (0..256).map(|i| ((i * 37) % 100) as f32 / 25.0).collect();
    let stats = b.run("nucleus sample V=256", || {
        std::hint::black_box(nucleus_sample(&logits, SampleParams::default(), &mut rng));
    });
    table.row(vec!["nucleus sample V=256".into(), format!("{:.2?}", stats.mean),
                   format!("{:.0} samp/s", 1.0 / stats.mean_secs())]);

    // rust reference attention: quadratic vs linear cost shape
    for (t, l) in [(128usize, 16usize), (256, 16)] {
        let inp = ref_inputs(t, l, 32);
        let sq = b.run("vqref quadratic", || {
            std::hint::black_box(vqref::quadratic_vq_attention(&inp));
        });
        let sl = b.run("vqref linear", || {
            std::hint::black_box(vqref::linear_vq_attention(&inp));
        });
        table.row(vec![format!("vqref T={t} quad"), format!("{:.2?}", sq.mean),
                       format!("{:.2} Mtok/s", t as f64 / sq.mean_secs() / 1e6)]);
        table.row(vec![format!("vqref T={t} linear"), format!("{:.2?}", sl.mean),
                       format!("{:.2} Mtok/s", t as f64 / sl.mean_secs() / 1e6)]);
    }
    table.print();
    println!("\nexpected shape: doubling T roughly doubles quadratic per-token \
              cost, leaves linear per-token cost flat (Remark 3.8).");
}

fn ref_inputs(t: usize, l: usize, s: usize) -> vqref::AttnInputs {
    let mut rng = Rng::new(3);
    let dk = 8;
    let dv = 8;
    let scale = 1.0 / (dk as f64).sqrt();
    let codebook: Vec<Vec<f64>> = (0..s)
        .map(|_| (0..dk).map(|_| rng.normal() * scale).collect())
        .collect();
    let mut k_hat = Vec::new();
    let mut z = Vec::new();
    for _ in 0..t {
        let raw: Vec<f64> = (0..dk).map(|_| rng.normal() * scale).collect();
        let c = vqref::nearest_code(&raw, &codebook);
        k_hat.push(codebook[c].clone());
        z.push(c);
    }
    vqref::AttnInputs {
        q: (0..t).map(|_| (0..dk).map(|_| rng.normal() * scale).collect()).collect(),
        k_hat,
        z,
        v: (0..t).map(|_| (0..dv).map(|_| rng.normal()).collect()).collect(),
        codebook,
        bias: (0..t).map(|_| (0..2 * l).map(|_| rng.normal() * 0.2).collect()).collect(),
        block_len: l,
    }
}
