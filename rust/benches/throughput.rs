//! `cargo bench --bench throughput` — paper Tables 6-9 (Full vs VQ training
//! throughput per head type / reduction / sequence length).
//!
//! Set TVQ_BENCH_MAX_T to limit sequence length (default 1024 under `cargo
//! bench` to keep the run short; the throughput_table example defaults to
//! the full grid).

use transformer_vq::bench::Bencher;
use transformer_vq::paperbench::{measure_throughput_grid, print_throughput_tables};
use transformer_vq::runtime::auto_backend;

fn main() {
    let max_t: usize = std::env::var("TVQ_BENCH_MAX_T")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024);
    let backend = auto_backend(transformer_vq::artifacts_dir()).unwrap();
    eprintln!("backend: {}", backend.platform());
    let bencher = Bencher {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 20,
        budget: std::time::Duration::from_secs(2),
    };
    let rows = measure_throughput_grid(backend.as_ref(), &bencher, max_t).unwrap();
    print_throughput_tables(&rows);
}
