//! `cargo bench --bench step_latency` — per-step wall time of the train /
//! eval / decode executors for the training presets (the latency column of
//! paper Tables 1-2 comes from the train-step latency here). Runs against
//! whatever backend is available: native always; PJRT artifacts when built
//! with `--features pjrt` and `make artifacts` has run.

use transformer_vq::bench::{Bencher, Table};
use transformer_vq::runtime::{auto_backend, StateBundle};

fn main() {
    let backend = auto_backend(transformer_vq::artifacts_dir()).unwrap();
    eprintln!("backend: {}", backend.platform());
    let bencher = Bencher {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: 40,
        budget: std::time::Duration::from_secs(3),
    };

    let mut table = Table::new(&["artifact", "mean/step", "median", "tok/s"]);
    for preset in ["quickstart", "enwik8-tiny", "ablate-S32", "ablate-S128"] {
        for entry in ["train", "eval", "decode"] {
            let name = format!("{preset}.{entry}");
            if !backend.has_artifact(&name) {
                continue;
            }
            let exe = backend.load(&name).unwrap();
            let mut bundle = StateBundle::zeros_for(exe.spec());
            if let Ok(init) = backend.init_state(preset) {
                bundle.set_named(init);
            }
            let inputs = bundle.assemble(exe.spec()).unwrap();
            let stats = bencher.run(&name, || {
                exe.run(&inputs).unwrap();
            });
            let cfg = &exe.spec().config;
            let tokens_per_step = if entry == "decode" {
                cfg.batch_size as f64
            } else {
                (cfg.window_len * cfg.batch_size) as f64
            };
            table.row(vec![
                name.clone(),
                format!("{:.3?}", stats.mean),
                format!("{:.3?}", stats.median),
                format!("{:.0}", tokens_per_step / stats.mean_secs()),
            ]);
        }
    }
    table.print();
}
