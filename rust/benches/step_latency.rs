//! `cargo bench --bench step_latency` — per-step wall time of the compiled
//! train / eval / decode artifacts for the training presets (the latency
//! column of paper Tables 1-2 comes from the train-step latency here), plus
//! the L3-side overhead split (literal conversion vs execution), which the
//! §Perf pass in EXPERIMENTS.md tracks.

use std::time::Instant;

use transformer_vq::bench::{Bencher, Table};
use transformer_vq::manifest::Manifest;
use transformer_vq::runtime::{Runtime, StateBundle};

fn main() {
    let dir = transformer_vq::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP step_latency bench: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let bencher = Bencher {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: 40,
        budget: std::time::Duration::from_secs(3),
    };

    let mut table = Table::new(&[
        "artifact", "mean/step", "median", "tok/s", "convert-in %",
    ]);
    for preset in ["quickstart", "enwik8-tiny", "ablate-S32", "ablate-S128"] {
        for entry in ["train", "eval", "decode"] {
            let name = format!("{preset}.{entry}");
            if manifest.get(&name).is_err() {
                continue;
            }
            let exe = runtime.load(&manifest, &name).unwrap();
            let mut bundle = StateBundle::zeros_for(&exe.spec);
            let init = manifest.init_path(preset);
            if init.exists() {
                bundle.load_groups(init).unwrap();
            }
            let inputs = bundle.assemble(&exe.spec).unwrap();

            // measure input literal conversion separately (L3 overhead)
            let t0 = Instant::now();
            let mut lits = exe.to_literals(&inputs).unwrap();
            let convert = t0.elapsed();
            let stats = bencher.run(&name, || {
                lits = exe.to_literals(&inputs).unwrap();
                exe.run_literals(&lits).unwrap();
            });
            let exec_only = bencher.run(&name, || {
                exe.run_literals(&lits).unwrap();
            });
            let tokens = match entry {
                "decode" => exe.spec.config.batch_size,
                _ => exe.spec.config.batch_size * exe.spec.config.window_len,
            } as f64;
            table.row(vec![
                name,
                format!("{:.3?}", stats.mean),
                format!("{:.3?}", stats.median),
                format!("{:.0}", tokens / stats.mean_secs()),
                format!(
                    "{:.1}%",
                    100.0 * (stats.mean_secs() - exec_only.mean_secs()).max(0.0)
                        / stats.mean_secs()
                ),
            ]);
            let _ = convert;
        }
    }
    table.print();
}
