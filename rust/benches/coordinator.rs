//! `cargo bench --bench coordinator` — serving-path benchmarks:
//! decode steps/sec, continuous-batching utilization under mixed loads,
//! and the wire-protocol overhead (JSON parse/serialize per request).

use std::sync::mpsc;
use std::time::Instant;

use transformer_vq::bench::{Bencher, Table};
use transformer_vq::coordinator::{Engine, GenRequest, WireRequest, WireResponse};
use transformer_vq::runtime::auto_backend;
use transformer_vq::sample::{SampleParams, Sampler};

fn main() {
    let bencher = Bencher { warmup_iters: 3, min_iters: 10, max_iters: 5000,
                            budget: std::time::Duration::from_secs(2) };

    // --- wire protocol micro-benchmarks (no artifacts needed) -------------
    let mut table = Table::new(&["bench", "mean", "ops/s"]);
    let req_line = WireRequest::new("a moderately sized prompt for parsing", 64)
        .to_json()
        .dump();
    let stats = bencher.run("wire request parse", || {
        let r = WireRequest::parse(&req_line).unwrap();
        std::hint::black_box(r);
    });
    table.row(vec!["request parse".into(), format!("{:.3?}", stats.mean),
                   format!("{:.0}", 1.0 / stats.mean_secs())]);
    let resp = WireResponse {
        ok: true,
        text: Some("x".repeat(128)),
        tokens: Some((0..128).collect()),
        prompt_tokens: Some(16),
        queue_ms: Some(0.1),
        gen_ms: Some(5.0),
        reason: Some("length".into()),
        error: None,
    };
    let stats = bencher.run("wire response serialize", || {
        std::hint::black_box(resp.to_json().dump());
    });
    table.row(vec!["response serialize".into(), format!("{:.3?}", stats.mean),
                   format!("{:.0}", 1.0 / stats.mean_secs())]);
    table.print();

    // --- engine benchmarks (native backend by default) ---------------------
    let dir = transformer_vq::artifacts_dir();

    // raw decode step rate (full batch)
    {
        let backend = auto_backend(&dir).unwrap();
        eprintln!("backend: {}", backend.platform());
        let mut sampler = Sampler::new(backend.as_ref(), "quickstart").unwrap();
        let b = sampler.batch_size();
        sampler.reset_all();
        let stats = Bencher { warmup_iters: 3, min_iters: 10, max_iters: 200,
                              budget: std::time::Duration::from_secs(3) }
            .run("decode step (full batch)", || {
                sampler.step(&vec![42; b]).unwrap();
            });
        println!(
            "\ndecode step: {:.3?}/step, {:.0} tok/s at batch {b}",
            stats.mean,
            b as f64 / stats.mean_secs()
        );
    }

    // continuous batching: aggregate throughput + utilization, mixed lengths
    {
        let dir2 = dir.clone();
        let (handle, join) = Engine::spawn(
            move || {
                let backend = auto_backend(&dir2)?;
                Sampler::new(backend.as_ref(), "quickstart")
            },
            7,
        )
        .unwrap();
        let n_requests = 24;
        let t0 = Instant::now();
        let (tx, rx) = mpsc::channel();
        for i in 0..n_requests {
            let handle = handle.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                let r = handle.generate(GenRequest {
                    prompt: vec![(i % 200) as i32 + 32],
                    max_tokens: 16 + (i % 5) * 16,
                    params: SampleParams::default(),
                    ..GenRequest::default()
                });
                tx.send(r.map(|x| x.tokens.len())).unwrap();
            });
        }
        drop(tx);
        let mut total = 0usize;
        while let Ok(r) = rx.recv() {
            total += r.unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        drop(handle);
        let stats = join.join().unwrap();
        println!(
            "continuous batching: {n_requests} reqs, {total} tokens in {wall:.2}s \
             ({:.0} tok/s), slot utilization {:.0}%",
            total as f64 / wall,
            100.0 * stats.utilization(4)
        );
    }
}
