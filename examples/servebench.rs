//! Native serving perf baseline: chunked prefill vs the old token-by-token
//! prompt path, plus streaming TTFT and steady-state decode throughput
//! under concurrent clients.
//!
//! The paper's serving claim is that every slot decodes in O(S + 2L)
//! forever; the session API built on it ingests prompts in chunks
//! (`Sampler::prefill`) instead of one full-batch `step` per prompt token.
//! Phase 1 measures that directly on the sampler: a P-token prompt costs
//! P full-batch decode steps on the old path (B lanes computed, B×V
//! logits discarded per token) vs ceil(P/C) single-lane prefill calls with
//! one readout. Phase 2 drives the whole stack — engine + TCP + NDJSON v2
//! frames — with N concurrent streaming clients and reports TTFT and
//! aggregate decode tok/s, asserting on the way that streamed deltas
//! concatenate to each request's final text.
//!
//! Phase 2 runs twice — once with the default batched-lane decode (all
//! occupied slots advance through each layer together, one GEMM per
//! projection) and once with the per-lane fallback — so the artifact
//! records how serving throughput under concurrent streams responds to
//! lane batching; the SIMD mode in effect is recorded alongside.
//!
//! Emits `BENCH_native_serve.json` (path overridable) so CI tracks the
//! serving trajectory next to the decode/train artifacts. See DESIGN.md §8
//! for how to read it.
//!
//! Usage: cargo run --release --example servebench --
//!        [preset] [prompt_len] [n_clients] [out.json]

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use transformer_vq::coordinator::{
    serve_on, Client, Engine, EngineStats, EventFrame, GenerateFrame,
};
use transformer_vq::json::Json;
use transformer_vq::native::{kernels, NativeBackend, NativeOptions};
use transformer_vq::sample::Sampler;

/// Aggregate results of one streaming run.
struct StreamingRun {
    ttft_ms_mean: f64,
    ttft_ms_max: f64,
    decode_tps: f64,
    wall: f64,
    stats: EngineStats,
}

/// Spawn an engine (with the given native options) + TCP server, run
/// `n_clients` concurrent streaming generations of `max_tokens` each, and
/// collect TTFT / steady-state decode throughput. Asserts per client that
/// streamed deltas concatenate to the final output.
fn streaming_phase(
    preset: &str,
    prompt_str: &str,
    n_clients: usize,
    max_tokens: usize,
    options: NativeOptions,
) -> Result<StreamingRun> {
    let preset_c = preset.to_string();
    let (handle, join) = Engine::spawn(
        move || Sampler::new(&NativeBackend::new().with_options(options), &preset_c),
        0,
    )?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let (sd_tx, sd_rx) = mpsc::channel();
    let server = {
        let handle = handle.clone();
        std::thread::spawn(move || serve_on(listener, handle, Some(sd_rx)))
    };

    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n_clients {
        let addr = addr.clone();
        let prompt_str = prompt_str.to_string();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let run = || -> Result<(f64, f64, usize)> {
                let mut client = Client::connect(&addr)?;
                let mut frame = GenerateFrame::new(format!("bench-{i}"), prompt_str, max_tokens);
                frame.seed = Some(7 + i as u64);
                let t_submit = Instant::now();
                client.generate(&frame)?;
                let mut ttft = None;
                let mut first_delta = None;
                let mut delta_text = String::new();
                let mut delta_tokens: Vec<i32> = Vec::new();
                loop {
                    match client.next_event()? {
                        EventFrame::Delta { token, text, .. } => {
                            ttft.get_or_insert_with(|| t_submit.elapsed().as_secs_f64() * 1e3);
                            first_delta.get_or_insert_with(Instant::now);
                            delta_text.push_str(&text);
                            delta_tokens.push(token);
                        }
                        EventFrame::Done { text, tokens, .. } => {
                            // CI smoke assertion: streamed deltas concatenate
                            // to the final output
                            anyhow::ensure!(tokens == delta_tokens, "delta tokens != done tokens");
                            anyhow::ensure!(
                                text.starts_with(&delta_text)
                                    && text[delta_text.len()..]
                                        .chars()
                                        .all(|c| c == '\u{FFFD}'),
                                "concatenated delta text does not match done text"
                            );
                            let decode_secs = first_delta
                                .map(|t| t.elapsed().as_secs_f64())
                                .unwrap_or(0.0);
                            return Ok((ttft.unwrap_or(0.0), decode_secs, tokens.len()));
                        }
                        EventFrame::Error { error, .. } => anyhow::bail!("{error}"),
                        EventFrame::Started { .. } | EventFrame::Stats(_) => {}
                    }
                }
            };
            tx.send(run()).unwrap();
        });
    }
    drop(tx);

    let mut ttfts = Vec::new();
    let mut decode_tokens = 0usize;
    let mut decode_secs_max = 0.0f64;
    while let Ok(r) = rx.recv() {
        let (ttft_ms, decode_secs, toks) = r?;
        ttfts.push(ttft_ms);
        decode_tokens += toks;
        decode_secs_max = decode_secs_max.max(decode_secs);
    }
    let wall = t0.elapsed().as_secs_f64();
    let decode_tps = if decode_secs_max > 0.0 {
        decode_tokens as f64 / decode_secs_max
    } else {
        0.0
    };
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ttft_ms_mean = ttfts.iter().sum::<f64>() / ttfts.len().max(1) as f64;
    let ttft_ms_max = ttfts.last().copied().unwrap_or(0.0);

    let _ = sd_tx.send(());
    server.join().expect("server thread")?;
    let stats = join.join().expect("engine thread");
    Ok(StreamingRun { ttft_ms_mean, ttft_ms_max, decode_tps, wall, stats })
}

/// Best-of-`reps` wall seconds for `f` (min is robust to scheduler noise).
fn best_secs(reps: usize, mut f: impl FnMut() -> Result<()>) -> Result<f64> {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "quickstart".into());
    let prompt_len: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(512);
    let n_clients: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(2);
    let out_path = args
        .get(3)
        .map(String::as_str)
        .unwrap_or("BENCH_native_serve.json");

    let backend = NativeBackend::new();
    let mut sampler = Sampler::new(&backend, &preset)?;
    let batch = sampler.batch_size();
    let chunk = sampler.prefill_chunk();
    let prompt: Vec<i32> = (0..prompt_len as i32).map(|t| 32 + (t * 7 + 13) % 94).collect();
    eprintln!(
        "servebench: {preset} (B={batch}, prefill chunk {chunk}), \
         prompt {prompt_len} tokens, {n_clients} streaming clients"
    );

    // --- phase 1: prompt ingestion, old path vs chunked prefill ------------
    // old path: what the pre-session engine did per prompt token — one
    // full-batch decode step, computing and discarding B×V logits
    let mut baseline_logits = Vec::new();
    let baseline_secs = best_secs(3, || {
        sampler.reset_all();
        for &t in &prompt {
            baseline_logits = sampler.step(&vec![t; batch])?.swap_remove(0);
        }
        Ok(())
    })?;
    // new path: chunked single-lane prefill, logits only after the last token
    let mut prefill_logits = Vec::new();
    let prefill_secs = best_secs(3, || {
        sampler.reset_all();
        prefill_logits = sampler.prefill(0, &prompt)?;
        Ok(())
    })?;
    assert_eq!(
        baseline_logits, prefill_logits,
        "prefill must reproduce the stepwise path bit-for-bit"
    );
    let baseline_tps = prompt_len as f64 / baseline_secs;
    let prefill_tps = prompt_len as f64 / prefill_secs;
    let speedup = prefill_tps / baseline_tps;
    println!("prompt ingestion ({prompt_len} tokens):");
    println!("  token-by-token (old engine path): {baseline_tps:>10.0} tok/s");
    println!("  chunked prefill (session path):   {prefill_tps:>10.0} tok/s");
    println!("  speedup: {speedup:.2}x");

    // --- phase 2: streaming serving under N concurrent clients, batched
    // lanes (the default) vs the per-lane fallback ---------------------------
    let max_tokens = 96usize;
    let prompt_str: String = prompt.iter().map(|&t| (t as u8) as char).collect();
    let defaults = NativeOptions::default();
    let batched = streaming_phase(&preset, &prompt_str, n_clients, max_tokens, defaults)?;
    let per_lane_opts = NativeOptions { batched_decode: false, ..defaults };
    let per_lane = streaming_phase(&preset, &prompt_str, n_clients, max_tokens, per_lane_opts)?;
    let batched_serve_speedup = if per_lane.decode_tps > 0.0 {
        batched.decode_tps / per_lane.decode_tps
    } else {
        0.0
    };

    println!("streaming ({n_clients} clients, {max_tokens} tokens each):");
    println!(
        "  batched lanes:  TTFT mean {:.1} ms, max {:.1} ms; decode {:.0} tok/s aggregate",
        batched.ttft_ms_mean, batched.ttft_ms_max, batched.decode_tps
    );
    println!(
        "  per-lane:       TTFT mean {:.1} ms, max {:.1} ms; decode {:.0} tok/s aggregate",
        per_lane.ttft_ms_mean, per_lane.ttft_ms_max, per_lane.decode_tps
    );
    println!("  batched-vs-per-lane serve speedup: {batched_serve_speedup:.2}x");
    println!(
        "  engine (batched run): {} prefill + {} decode tokens over {} steps in {:.2}s",
        batched.stats.prefill_tokens,
        batched.stats.decode_tokens,
        batched.stats.steps,
        batched.wall
    );

    let j = Json::obj(vec![
        ("bench", Json::str("native_serve")),
        ("preset", Json::str(preset)),
        ("batch", Json::num(batch as f64)),
        ("prefill_chunk", Json::num(chunk as f64)),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("cores", Json::num(kernels::default_threads() as f64)),
        ("simd_mode", Json::str(defaults.simd.name())),
        ("baseline_prefill_tok_s", Json::num(baseline_tps)),
        ("chunked_prefill_tok_s", Json::num(prefill_tps)),
        ("prefill_speedup", Json::num(speedup)),
        ("n_clients", Json::num(n_clients as f64)),
        ("max_tokens", Json::num(max_tokens as f64)),
        ("ttft_ms_mean", Json::num(batched.ttft_ms_mean)),
        ("ttft_ms_max", Json::num(batched.ttft_ms_max)),
        ("decode_tok_s", Json::num(batched.decode_tps)),
        ("ttft_ms_mean_per_lane", Json::num(per_lane.ttft_ms_mean)),
        ("decode_tok_s_per_lane", Json::num(per_lane.decode_tps)),
        ("batched_serve_speedup", Json::num(batched_serve_speedup)),
        ("engine_prefill_tokens", Json::num(batched.stats.prefill_tokens as f64)),
        ("engine_decode_tokens", Json::num(batched.stats.decode_tokens as f64)),
        ("engine_steps", Json::num(batched.stats.steps as f64)),
        ("utilization", Json::num(batched.stats.utilization(batch))),
    ]);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");

    assert!(
        speedup >= 1.5,
        "chunked prefill must clearly beat the token-by-token path, got {speedup:.2}x"
    );
    println!("servebench OK: chunked prefill {speedup:.2}x over token-by-token ingestion");
    Ok(())
}
