//! Native serving perf baseline: chunked prefill vs the old token-by-token
//! prompt path, plus streaming TTFT and steady-state decode throughput
//! under concurrent clients.
//!
//! The paper's serving claim is that every slot decodes in O(S + 2L)
//! forever; the session API built on it ingests prompts in chunks
//! (`Sampler::prefill`) instead of one full-batch `step` per prompt token.
//! Phase 1 measures that directly on the sampler: a P-token prompt costs
//! P full-batch decode steps on the old path (B lanes computed, B×V
//! logits discarded per token) vs ceil(P/C) single-lane prefill calls with
//! one readout. Phase 2 times lane snapshot/restore (session state as a
//! value, DESIGN.md §10) and records the wire size. Phase 3 times a
//! prompt-prefix-cache hit against a cold prefill and pins bit-identity
//! by continuing one decode step both ways. Phase 4 drives the whole
//! stack — engine + TCP + NDJSON v2 frames — with N concurrent streaming
//! clients and reports TTFT and aggregate decode tok/s, asserting on the
//! way that streamed deltas concatenate to each request's final text.
//!
//! Phase 4 runs three times — default batched-lane decode (all occupied
//! slots advance through each layer together, one GEMM per projection),
//! the per-lane fallback, and batched again with the prefix cache on — so
//! the artifact records how serving throughput responds to lane batching
//! and how TTFT responds to prefix caching (with a cross-run assert that
//! the cache never changes a sampled token); the SIMD mode in effect is
//! recorded alongside.
//!
//! Emits `BENCH_native_serve.json` (path overridable) so CI tracks the
//! serving trajectory next to the decode/train artifacts. See DESIGN.md §8
//! for how to read it.
//!
//! Usage: cargo run --release --example servebench --
//!        [preset] [prompt_len] [n_clients] [out.json]

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use transformer_vq::coordinator::{
    serve_on, Client, Engine, EngineStats, EventFrame, GenerateFrame,
};
use transformer_vq::json::Json;
use transformer_vq::native::{kernels, preset_config, LaneSnapshot, NativeBackend, NativeOptions};
use transformer_vq::sample::Sampler;

/// Aggregate results of one streaming run.
struct StreamingRun {
    ttft_ms_mean: f64,
    ttft_ms_max: f64,
    decode_tps: f64,
    wall: f64,
    stats: EngineStats,
    /// Per-client generated tokens, client order — lets the caller assert
    /// that a configuration change (e.g. the prefix cache) did not change
    /// a single sampled token.
    outputs: Vec<Vec<i32>>,
}

/// Spawn an engine (with the given native options) + TCP server, run
/// `n_clients` concurrent streaming generations of `max_tokens` each, and
/// collect TTFT / steady-state decode throughput. Asserts per client that
/// streamed deltas concatenate to the final output.
fn streaming_phase(
    preset: &str,
    prompt_str: &str,
    n_clients: usize,
    max_tokens: usize,
    options: NativeOptions,
    prefix_cache: usize,
) -> Result<StreamingRun> {
    let preset_c = preset.to_string();
    let (handle, join) = Engine::spawn(
        move || {
            let mut s = Sampler::new(&NativeBackend::new().with_options(options), &preset_c)?;
            if prefix_cache > 0 {
                s.enable_prefix_cache(prefix_cache);
            }
            Ok(s)
        },
        0,
    )?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let (sd_tx, sd_rx) = mpsc::channel();
    let server = {
        let handle = handle.clone();
        std::thread::spawn(move || serve_on(listener, handle, Some(sd_rx)))
    };

    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n_clients {
        let addr = addr.clone();
        let prompt_str = prompt_str.to_string();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let run = || -> Result<(f64, f64, Vec<i32>)> {
                let mut client = Client::connect(&addr)?;
                let mut frame = GenerateFrame::new(format!("bench-{i}"), prompt_str, max_tokens);
                frame.seed = Some(7 + i as u64);
                let t_submit = Instant::now();
                client.generate(&frame)?;
                let mut ttft = None;
                let mut first_delta = None;
                let mut delta_text = String::new();
                let mut delta_tokens: Vec<i32> = Vec::new();
                loop {
                    match client.next_event()? {
                        EventFrame::Delta { token, text, .. } => {
                            ttft.get_or_insert_with(|| t_submit.elapsed().as_secs_f64() * 1e3);
                            first_delta.get_or_insert_with(Instant::now);
                            delta_text.push_str(&text);
                            delta_tokens.push(token);
                        }
                        EventFrame::Done { text, tokens, .. } => {
                            // CI smoke assertion: streamed deltas concatenate
                            // to the final output
                            anyhow::ensure!(tokens == delta_tokens, "delta tokens != done tokens");
                            anyhow::ensure!(
                                text.starts_with(&delta_text)
                                    && text[delta_text.len()..]
                                        .chars()
                                        .all(|c| c == '\u{FFFD}'),
                                "concatenated delta text does not match done text"
                            );
                            let decode_secs = first_delta
                                .map(|t| t.elapsed().as_secs_f64())
                                .unwrap_or(0.0);
                            return Ok((ttft.unwrap_or(0.0), decode_secs, tokens));
                        }
                        EventFrame::Error { error, .. } => anyhow::bail!("{error}"),
                        EventFrame::Started { .. } | EventFrame::Stats(_) => {}
                    }
                }
            };
            tx.send((i, run())).unwrap();
        });
    }
    drop(tx);

    let mut ttfts = Vec::new();
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); n_clients];
    let mut decode_tokens = 0usize;
    let mut decode_secs_max = 0.0f64;
    while let Ok((i, r)) = rx.recv() {
        let (ttft_ms, decode_secs, toks) = r?;
        ttfts.push(ttft_ms);
        decode_tokens += toks.len();
        decode_secs_max = decode_secs_max.max(decode_secs);
        outputs[i] = toks;
    }
    let wall = t0.elapsed().as_secs_f64();
    let decode_tps = if decode_secs_max > 0.0 {
        decode_tokens as f64 / decode_secs_max
    } else {
        0.0
    };
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ttft_ms_mean = ttfts.iter().sum::<f64>() / ttfts.len().max(1) as f64;
    let ttft_ms_max = ttfts.last().copied().unwrap_or(0.0);

    let _ = sd_tx.send(());
    server.join().expect("server thread")?;
    let stats = join.join().expect("engine thread");
    Ok(StreamingRun { ttft_ms_mean, ttft_ms_max, decode_tps, wall, stats, outputs })
}

/// Best-of-`reps` wall seconds for `f` (min is robust to scheduler noise).
fn best_secs(reps: usize, mut f: impl FnMut() -> Result<()>) -> Result<f64> {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "quickstart".into());
    let prompt_len: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(512);
    let n_clients: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(2);
    let out_path = args
        .get(3)
        .map(String::as_str)
        .unwrap_or("BENCH_native_serve.json");

    let backend = NativeBackend::new();
    let mut sampler = Sampler::new(&backend, &preset)?;
    let batch = sampler.batch_size();
    let chunk = sampler.prefill_chunk();
    let prompt: Vec<i32> = (0..prompt_len as i32).map(|t| 32 + (t * 7 + 13) % 94).collect();
    eprintln!(
        "servebench: {preset} (B={batch}, prefill chunk {chunk}), \
         prompt {prompt_len} tokens, {n_clients} streaming clients"
    );

    // --- phase 1: prompt ingestion, old path vs chunked prefill ------------
    // old path: what the pre-session engine did per prompt token — one
    // full-batch decode step, computing and discarding B×V logits
    let mut baseline_logits = Vec::new();
    let baseline_secs = best_secs(3, || {
        sampler.reset_all();
        for &t in &prompt {
            baseline_logits = sampler.step(&vec![t; batch])?.swap_remove(0);
        }
        Ok(())
    })?;
    // new path: chunked single-lane prefill, logits only after the last token
    let mut prefill_logits = Vec::new();
    let prefill_secs = best_secs(3, || {
        sampler.reset_all();
        prefill_logits = sampler.prefill(0, &prompt)?;
        Ok(())
    })?;
    assert_eq!(
        baseline_logits, prefill_logits,
        "prefill must reproduce the stepwise path bit-for-bit"
    );
    let baseline_tps = prompt_len as f64 / baseline_secs;
    let prefill_tps = prompt_len as f64 / prefill_secs;
    let speedup = prefill_tps / baseline_tps;
    println!("prompt ingestion ({prompt_len} tokens):");
    println!("  token-by-token (old engine path): {baseline_tps:>10.0} tok/s");
    println!("  chunked prefill (session path):   {prefill_tps:>10.0} tok/s");
    println!("  speedup: {speedup:.2}x");

    // --- phase 2: snapshot/restore — session state as a value --------------
    // The per-lane state is O(model), so shipping a lane out of a live
    // session (and back) should cost microseconds. Measured on a lane
    // holding the full prompt, i.e. the worst realistic state.
    let cfg = preset_config(&preset)?;
    sampler.reset_all();
    sampler.prefill(0, &prompt)?;
    let mut wire: Vec<u8> = Vec::new();
    let snapshot_secs = best_secs(5, || {
        wire = sampler.snapshot_slot(0)?.encode(&cfg)?;
        Ok(())
    })?;
    let restore_secs = best_secs(5, || {
        let snap = LaneSnapshot::decode(&cfg, &wire)?;
        sampler.restore_slot(0, &snap)
    })?;
    println!("snapshot/restore (one lane, {} bytes on the wire):", wire.len());
    println!("  snapshot+encode: {:>8.1} us", snapshot_secs * 1e6);
    println!("  decode+restore:  {:>8.1} us", restore_secs * 1e6);

    // --- phase 3: prefix-cache hit vs cold prefill on the sampler ----------
    // A hit replaces ceil(P/C) prefill dispatches with one lane restore;
    // the restored state plus stored logits must be bit-identical to a
    // cold prefill, pinned here by continuing one decode step both ways.
    sampler.enable_prefix_cache(4);
    sampler.reset_all();
    let cold_logits = sampler.prefill(0, &prompt)?;
    sampler.prefix_insert(&prompt, 0, &cold_logits)?;
    let cont = vec![32i32; batch];
    let cold_next = sampler.step(&cont)?.swap_remove(0);
    let mut hit_logits = Vec::new();
    let hit_secs = best_secs(5, || {
        sampler.reset_all();
        let (matched, logits) = sampler
            .prefix_lookup(0, &prompt)?
            .ok_or_else(|| anyhow::anyhow!("expected a prefix-cache hit"))?;
        anyhow::ensure!(matched == prompt.len(), "partial hit on an exact prompt");
        hit_logits = logits.ok_or_else(|| anyhow::anyhow!("exact hit must carry logits"))?;
        Ok(())
    })?;
    let hit_next = sampler.step(&cont)?.swap_remove(0);
    assert_eq!(
        (cold_logits, cold_next),
        (hit_logits, hit_next),
        "prefix-cache hit must be bit-identical to a cold prefill"
    );
    let hit_speedup = prefill_secs / hit_secs.max(1e-9);
    println!("prompt ingestion via prefix-cache hit:");
    println!("  lookup+restore: {:>8.1} us ({hit_speedup:.0}x over cold prefill)", hit_secs * 1e6);

    // --- phase 4: streaming serving under N concurrent clients, batched
    // lanes (the default) vs the per-lane fallback vs prefix-cache on -------
    let max_tokens = 96usize;
    let prompt_str: String = prompt.iter().map(|&t| (t as u8) as char).collect();
    let defaults = NativeOptions::default();
    let batched = streaming_phase(&preset, &prompt_str, n_clients, max_tokens, defaults, 0)?;
    let per_lane_opts = NativeOptions { batched_decode: false, ..defaults };
    let per_lane =
        streaming_phase(&preset, &prompt_str, n_clients, max_tokens, per_lane_opts, 0)?;
    let cached = streaming_phase(&preset, &prompt_str, n_clients, max_tokens, defaults, 8)?;
    // same seeds, same prompts: the cache may change *when* logits appear,
    // never *which* tokens are sampled
    assert_eq!(
        cached.outputs, batched.outputs,
        "prefix cache changed sampled tokens under identical seeds"
    );
    let prefix_hit_rate = cached.stats.prefix_hit_tokens as f64
        / (cached.stats.prefill_tokens + cached.stats.prefix_hit_tokens).max(1) as f64;
    let batched_serve_speedup = if per_lane.decode_tps > 0.0 {
        batched.decode_tps / per_lane.decode_tps
    } else {
        0.0
    };

    println!("streaming ({n_clients} clients, {max_tokens} tokens each):");
    println!(
        "  batched lanes:  TTFT mean {:.1} ms, max {:.1} ms; decode {:.0} tok/s aggregate",
        batched.ttft_ms_mean, batched.ttft_ms_max, batched.decode_tps
    );
    println!(
        "  per-lane:       TTFT mean {:.1} ms, max {:.1} ms; decode {:.0} tok/s aggregate",
        per_lane.ttft_ms_mean, per_lane.ttft_ms_max, per_lane.decode_tps
    );
    println!("  batched-vs-per-lane serve speedup: {batched_serve_speedup:.2}x");
    println!(
        "  prefix cache:   TTFT mean {:.1} ms ({:+.1} ms vs off); {} hits, {} of {} prompt \
         tokens served from cache ({:.0}%)",
        cached.ttft_ms_mean,
        cached.ttft_ms_mean - batched.ttft_ms_mean,
        cached.stats.prefix_hits,
        cached.stats.prefix_hit_tokens,
        cached.stats.prefill_tokens + cached.stats.prefix_hit_tokens,
        prefix_hit_rate * 100.0
    );
    println!(
        "  engine (batched run): {} prefill + {} decode tokens over {} steps in {:.2}s",
        batched.stats.prefill_tokens,
        batched.stats.decode_tokens,
        batched.stats.steps,
        batched.wall
    );

    let j = Json::obj(vec![
        ("bench", Json::str("native_serve")),
        ("preset", Json::str(preset)),
        ("batch", Json::num(batch as f64)),
        ("prefill_chunk", Json::num(chunk as f64)),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("cores", Json::num(kernels::default_threads() as f64)),
        ("simd_mode", Json::str(defaults.simd.name())),
        ("baseline_prefill_tok_s", Json::num(baseline_tps)),
        ("chunked_prefill_tok_s", Json::num(prefill_tps)),
        ("prefill_speedup", Json::num(speedup)),
        ("n_clients", Json::num(n_clients as f64)),
        ("max_tokens", Json::num(max_tokens as f64)),
        ("ttft_ms_mean", Json::num(batched.ttft_ms_mean)),
        ("ttft_ms_max", Json::num(batched.ttft_ms_max)),
        ("decode_tok_s", Json::num(batched.decode_tps)),
        ("ttft_ms_mean_per_lane", Json::num(per_lane.ttft_ms_mean)),
        ("decode_tok_s_per_lane", Json::num(per_lane.decode_tps)),
        ("batched_serve_speedup", Json::num(batched_serve_speedup)),
        ("snapshot_bytes", Json::num(wire.len() as f64)),
        ("snapshot_encode_us", Json::num(snapshot_secs * 1e6)),
        ("snapshot_restore_us", Json::num(restore_secs * 1e6)),
        ("prefix_hit_us", Json::num(hit_secs * 1e6)),
        ("prefix_hit_speedup", Json::num(hit_speedup)),
        ("ttft_ms_mean_cached", Json::num(cached.ttft_ms_mean)),
        ("decode_tok_s_cached", Json::num(cached.decode_tps)),
        ("prefix_hits", Json::num(cached.stats.prefix_hits as f64)),
        ("prefix_hit_tokens", Json::num(cached.stats.prefix_hit_tokens as f64)),
        ("prefix_hit_rate", Json::num(prefix_hit_rate)),
        ("engine_prefill_tokens", Json::num(batched.stats.prefill_tokens as f64)),
        ("engine_decode_tokens", Json::num(batched.stats.decode_tokens as f64)),
        ("engine_steps", Json::num(batched.stats.steps as f64)),
        ("utilization", Json::num(batched.stats.utilization(batch))),
    ]);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");

    assert!(
        speedup >= 1.5,
        "chunked prefill must clearly beat the token-by-token path, got {speedup:.2}x"
    );
    println!("servebench OK: chunked prefill {speedup:.2}x over token-by-token ingestion");
    Ok(())
}
