//! Serving demo: start the continuous-batching coordinator in-process, fire
//! concurrent client requests at it, and report latency/throughput — the
//! serving-side payoff of linear-time attention (no per-token cost growth,
//! so slots interleave freely).
//!
//! Usage: cargo run --release --example serve -- [preset] [n_requests]

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use transformer_vq::coordinator::{handle_conn, Client, Engine, WireRequest};
use transformer_vq::metrics::LatencyHistogram;
use transformer_vq::runtime::auto_backend;
use transformer_vq::sample::Sampler;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "quickstart".into());
    let n_requests: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);

    let artifacts = transformer_vq::artifacts_dir();
    let ckpt = std::path::PathBuf::from(format!("runs/train_lm-{preset}/ckpt-final/state.tvq"));
    let preset_c = preset.clone();
    let (handle, _join) = Engine::spawn(
        move || {
            // backends may not be Send; build on the engine thread
            let backend = auto_backend(&artifacts)?;
            let mut s = Sampler::new(backend.as_ref(), &preset_c)?;
            if ckpt.exists() {
                s.load_weights(&ckpt)?;
            }
            Ok(s)
        },
        0,
    )?;

    // TCP front-end on an ephemeral port
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    {
        let handle = handle.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, h);
                });
            }
        });
    }
    eprintln!("serving {preset} on {addr}; firing {n_requests} concurrent requests");

    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n_requests {
        let addr = addr.clone();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let run = || -> Result<(f64, usize)> {
                let mut client = Client::connect(&addr)?;
                let t = Instant::now();
                let resp = client.request(&WireRequest {
                    prompt: format!("request {i}: the "),
                    max_tokens: 24 + (i % 4) * 16, // mixed lengths
                    temperature: 1.0,
                    top_p: 0.95,
                })?;
                anyhow::ensure!(resp.ok, "{:?}", resp.error);
                Ok((t.elapsed().as_secs_f64(), resp.tokens.unwrap().len()))
            };
            tx.send(run()).unwrap();
        });
    }
    drop(tx);

    let mut hist = LatencyHistogram::new();
    let mut total_tokens = 0usize;
    let mut done = 0;
    while let Ok(r) = rx.recv() {
        let (secs, toks) = r?;
        hist.record(std::time::Duration::from_secs_f64(secs));
        total_tokens += toks;
        done += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("== serving summary ==");
    println!("requests:        {done}/{n_requests}");
    println!(
        "generated:       {total_tokens} tokens in {wall:.2}s ({:.0} tok/s aggregate)",
        total_tokens as f64 / wall
    );
    println!("latency  mean:   {:?}", hist.mean());
    println!("latency  p50:    {:?}", hist.quantile(0.5));
    println!("latency  p99:    {:?}", hist.quantile(0.99));
    Ok(())
}
