//! Serving demo: start the continuous-batching coordinator in-process,
//! multiplex concurrent v2 streaming requests over TCP (chunked prefill,
//! per-token deltas, one mid-stream cancel), and report TTFT/latency —
//! the serving-side payoff of linear-time attention (no per-token cost
//! growth, so slots interleave freely and prompts ingest in chunks).
//!
//! Usage: cargo run --release --example serve -- [preset] [n_requests]

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;
use transformer_vq::coordinator::{serve_on, Client, Engine, EventFrame, GenerateFrame};
use transformer_vq::metrics::LatencyHistogram;
use transformer_vq::runtime::auto_backend;
use transformer_vq::sample::Sampler;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "quickstart".into());
    let n_requests: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);

    let artifacts = transformer_vq::artifacts_dir();
    let ckpt = std::path::PathBuf::from(format!("runs/train_lm-{preset}/ckpt-final/state.tvq"));
    let preset_c = preset.clone();
    let (handle, join) = Engine::spawn(
        move || {
            // backends may not be Send; build on the engine thread
            let backend = auto_backend(&artifacts)?;
            let mut s = Sampler::new(backend.as_ref(), &preset_c)?;
            if ckpt.exists() {
                s.load_weights(&ckpt)?;
            }
            Ok(s)
        },
        0,
    )?;

    // TCP front-end on an ephemeral port, graceful shutdown armed
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let (sd_tx, sd_rx) = mpsc::channel();
    let server = {
        let handle = handle.clone();
        std::thread::spawn(move || serve_on(listener, handle, Some(sd_rx)))
    };
    eprintln!("serving {preset} on {addr}; {n_requests} multiplexed streaming requests");

    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n_requests {
        let addr = addr.clone();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let run = || -> Result<(f64, f64, usize, bool)> {
                let mut client = Client::connect(&addr)?;
                let mut frame = GenerateFrame::new(
                    format!("req-{i}"),
                    format!("request {i}: the "),
                    24 + (i % 4) * 16, // mixed lengths
                );
                frame.seed = Some(1000 + i as u64);
                client.generate(&frame)?;
                let t = Instant::now();
                let mut ttft = None;
                let mut cancelled = false;
                loop {
                    match client.next_event()? {
                        EventFrame::Delta { index, .. } => {
                            ttft.get_or_insert_with(|| t.elapsed().as_secs_f64());
                            // demo cancellation: request 0 bails mid-stream
                            if i == 0 && index == 4 && !cancelled {
                                client.cancel(&frame.id)?;
                                cancelled = true;
                            }
                        }
                        EventFrame::Done { reason, tokens, .. } => {
                            let lat = t.elapsed().as_secs_f64();
                            return Ok((
                                ttft.unwrap_or(lat),
                                lat,
                                tokens.len(),
                                reason == "cancelled",
                            ));
                        }
                        EventFrame::Error { error, .. } => anyhow::bail!("{error}"),
                        EventFrame::Started { .. } | EventFrame::Stats(_) => {}
                    }
                }
            };
            tx.send(run()).unwrap();
        });
    }
    drop(tx);

    let mut ttft_hist = LatencyHistogram::new();
    let mut lat_hist = LatencyHistogram::new();
    let mut total_tokens = 0usize;
    let mut done = 0;
    let mut cancelled = 0;
    while let Ok(r) = rx.recv() {
        let (ttft, lat, toks, was_cancelled) = r?;
        ttft_hist.record(std::time::Duration::from_secs_f64(ttft));
        lat_hist.record(std::time::Duration::from_secs_f64(lat));
        total_tokens += toks;
        done += 1;
        cancelled += was_cancelled as usize;
    }
    let wall = t0.elapsed().as_secs_f64();

    // graceful shutdown: drain, join, report engine-side stats
    let stats = handle.stats().map_err(anyhow::Error::msg)?;
    let _ = sd_tx.send(());
    server.join().expect("server thread")?;
    let final_stats = join.join().expect("engine thread");

    println!("== serving summary ==");
    println!("requests:        {done}/{n_requests} ({cancelled} cancelled mid-stream)");
    println!(
        "generated:       {total_tokens} tokens in {wall:.2}s ({:.0} tok/s aggregate)",
        total_tokens as f64 / wall
    );
    println!("TTFT     mean:   {:?}", ttft_hist.mean());
    println!("TTFT     p99:    {:?}", ttft_hist.quantile(0.99));
    println!("latency  mean:   {:?}", lat_hist.mean());
    println!("latency  p50:    {:?}", lat_hist.quantile(0.5));
    println!("latency  p99:    {:?}", lat_hist.quantile(0.99));
    println!(
        "engine:          {} prefill + {} decode tokens, {} steps, \
         utilization {:.0}%, mean TTFT {:.1} ms",
        stats.prefill_tokens,
        stats.decode_tokens,
        stats.steps,
        100.0 * final_stats.utilization(4),
        final_stats.mean_ttft_ms(),
    );
    Ok(())
}
