//! Skewed-load traffic bench for the multi-replica serving fleet
//! (DESIGN.md §11): a session-affinity router over N engine replicas,
//! driven by Zipf-distributed prompt popularity and request lengths from
//! many concurrent connections.
//!
//! Phase 1 pins correctness under routing: a fixed-seed request set runs
//! once through a single engine and once through the fleet — outputs must
//! be bit-identical, including across a forced mid-stream migration
//! (evict at a token boundary, restore on another replica, continue).
//!
//! Phase 2 is the load test: `conns` client connections, each issuing
//! `reqs_per_conn` streaming requests over TCP against the fleet server.
//! Prompt choice follows Zipf(s=1.1) over a 64-prompt pool (popular
//! prompts concentrate on their affinity replica's prefix state),
//! completion lengths follow Zipf(s=1.2) over [8, 96] (short requests
//! dominate, a heavy tail runs long), and a slice of requests carries
//! tight deadlines so admission control has something to shed. A driver
//! thread calls `rebalance()` throughout, so live migrations happen under
//! fire. Reports saturation decode throughput, TTFT p50/p95/p99, shed
//! rate, and migration counts.
//!
//! Emits `BENCH_native_fleet.json` (path overridable) — the fourth CI
//! perf artifact, next to decode/train/serve.
//!
//! Usage: cargo run --release --example fleetbench --
//!        [preset] [replicas] [conns] [reqs_per_conn] [out.json]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;
use transformer_vq::coordinator::{
    serve_on, Client, Engine, EventFrame, Frontend, GenRequest, GenerateFrame, RequestEvents,
};
use transformer_vq::data::{ZipfLengths, ZipfSampler};
use transformer_vq::fleet::{Fleet, FleetHandle, FleetOptions};
use transformer_vq::json::Json;
use transformer_vq::native::NativeBackend;
use transformer_vq::rng::Rng;
use transformer_vq::sample::{SampleParams, Sampler};

/// Deterministic 64-prompt pool, ordered hot-first (rank 0 = most popular).
fn prompt_pool() -> Vec<String> {
    (0..64)
        .map(|i| {
            let stem = match i % 4 {
                0 => "the cache holds",
                1 => "attention over codes",
                2 => "linear time decode",
                _ => "quantized keys",
            };
            format!("{stem} #{i:02} ")
        })
        .collect()
}

fn spawn_fleet(
    preset: &str,
    replicas: usize,
    queue_depth: usize,
) -> Result<(FleetHandle, transformer_vq::fleet::FleetJoin)> {
    let preset = preset.to_string();
    let opts = FleetOptions { replicas, queue_depth, shed_deadline_ms: Some(5), faults: None };
    Fleet::spawn(
        opts,
        move |_replica| Sampler::new(&NativeBackend::new(), &preset),
        42,
    )
}

fn req(prompt: &str, max_tokens: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: prompt.bytes().map(i32::from).collect(),
        max_tokens,
        params: SampleParams::default(),
        seed: Some(seed),
        ..GenRequest::default()
    }
}

/// Phase 1: fixed-seed outputs must not depend on routing — or on a forced
/// mid-stream migration.
fn identity_phase(preset: &str, replicas: usize) -> Result<()> {
    let pool = prompt_pool();
    let cases: Vec<(String, usize, u64)> = (0..6)
        .map(|i| (pool[i * 7 % pool.len()].clone(), 24 + 8 * (i % 3), 1000 + i as u64))
        .collect();

    // reference: one bare engine
    let preset_c = preset.to_string();
    let (engine, ejoin) =
        Engine::spawn(move || Sampler::new(&NativeBackend::new(), &preset_c), 42)?;
    let mut want = Vec::new();
    for (p, n, s) in &cases {
        let rh = engine.submit(req(p, *n, *s)).map_err(|e| anyhow::anyhow!(e))?;
        want.push(rh.wait_outcome().map_err(|e| anyhow::anyhow!(e))?.tokens);
    }
    engine.shutdown();
    let _ = ejoin.join();

    // fleet, plain routing
    let (fleet, join) = spawn_fleet(preset, replicas, 8)?;
    for (i, (p, n, s)) in cases.iter().enumerate() {
        let rh = fleet
            .submit_session(&format!("ident-{i}"), req(p, *n, *s))
            .map_err(|e| anyhow::anyhow!("{:?}", e))?;
        let got = rh.wait_outcome().map_err(|e| anyhow::anyhow!(e))?.tokens;
        anyhow::ensure!(got == want[i], "fleet output diverged from single engine (case {i})");
    }

    // fleet, forced mid-stream migration: start a long request, read one
    // delta, bounce the session to every other replica in turn, drain
    let (p, _, s) = &cases[0];
    let long = req(p, 48, *s);
    let session = "ident-migrate";
    let rh = fleet
        .submit_session(session, long.clone())
        .map_err(|e| anyhow::anyhow!("{:?}", e))?;
    let mut got = Vec::new();
    let mut moved = 0usize;
    loop {
        match rh.recv_event().map_err(|e| anyhow::anyhow!(e))? {
            transformer_vq::coordinator::GenEvent::Delta { token, .. } => {
                got.push(token);
                if moved < replicas.max(2) {
                    let dst = (fleet.session_replica(session).unwrap_or(0) + 1) % replicas;
                    if fleet.migrate(session, dst).map_err(|e| anyhow::anyhow!(e))? {
                        moved += 1;
                    }
                }
            }
            transformer_vq::coordinator::GenEvent::Done(o) => {
                anyhow::ensure!(o.tokens == got, "deltas disagree with final tokens");
                break;
            }
            transformer_vq::coordinator::GenEvent::Error(e) => anyhow::bail!(e),
            transformer_vq::coordinator::GenEvent::Started { .. } => {}
        }
    }
    anyhow::ensure!(moved >= 1, "migration never happened — oracle did not exercise the move");
    // the migrated stream must equal the same request run without moving
    let rh = fleet
        .submit_session("ident-stay", long)
        .map_err(|e| anyhow::anyhow!("{:?}", e))?;
    let stay = rh.wait_outcome().map_err(|e| anyhow::anyhow!(e))?.tokens;
    anyhow::ensure!(got == stay, "mid-stream migration changed sampled tokens");

    let migrations = fleet.stats().migrations;
    fleet.shutdown_all();
    let _ = join.join();
    println!(
        "identity: fleet == engine on {} cases; {migrations} forced migrations bit-identical",
        cases.len()
    );
    Ok(())
}

struct ConnReport {
    ttfts_ms: Vec<f64>,
    tokens: usize,
    completed: usize,
    shed: usize,
    errors: usize,
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "quickstart".into());
    let replicas: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let conns: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(32);
    let reqs_per_conn: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let out_path = args.get(4).map(String::as_str).unwrap_or("BENCH_native_fleet.json");
    anyhow::ensure!(replicas >= 2, "fleetbench needs at least 2 replicas");

    eprintln!("fleetbench: {preset}, {replicas} replicas, {conns} conns x {reqs_per_conn} reqs");
    identity_phase(&preset, replicas)?;

    // --- phase 2: skewed traffic over TCP ----------------------------------
    let (fleet, join) = spawn_fleet(&preset, replicas, 4)?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let (sd_tx, sd_rx) = mpsc::channel();
    let server = {
        let fleet = fleet.clone();
        std::thread::spawn(move || serve_on(listener, fleet, Some(sd_rx)))
    };
    // rebalance driver: migrations under fire
    let stop = Arc::new(AtomicBool::new(false));
    let driver = {
        let fleet = fleet.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let _ = fleet.rebalance();
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        })
    };

    let pool = Arc::new(prompt_pool());
    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for c in 0..conns {
        let addr = addr.clone();
        let pool = Arc::clone(&pool);
        let tx = tx.clone();
        std::thread::spawn(move || {
            let run = || -> Result<ConnReport> {
                // per-connection deterministic traffic trace
                let mut rng = Rng::new(9000 + c as u64);
                let popularity = ZipfSampler::new(pool.len(), 1.1)?;
                let lengths = ZipfLengths::new(8, 96, 1.2)?;
                let mut rep = ConnReport {
                    ttfts_ms: Vec::new(),
                    tokens: 0,
                    completed: 0,
                    shed: 0,
                    errors: 0,
                };
                let mut client = Client::connect(&addr)?;
                for r in 0..reqs_per_conn {
                    let prompt = &pool[popularity.sample(&mut rng)];
                    let mut frame = GenerateFrame::new(
                        format!("c{c}-r{r}"),
                        prompt.clone(),
                        lengths.sample(&mut rng),
                    );
                    frame.seed = Some(rng.next_u64());
                    if r % 7 == 3 {
                        // a slice of traffic is latency-critical: under
                        // queueing these shed with a typed reason
                        frame.deadline_ms = Some(2);
                    }
                    let t_submit = Instant::now();
                    client.generate(&frame)?;
                    let mut ttft = None;
                    loop {
                        match client.next_event()? {
                            EventFrame::Delta { token: _, .. } => {
                                ttft.get_or_insert_with(|| {
                                    t_submit.elapsed().as_secs_f64() * 1e3
                                });
                            }
                            EventFrame::Done { tokens, .. } => {
                                rep.tokens += tokens.len();
                                rep.completed += 1;
                                if let Some(ms) = ttft {
                                    rep.ttfts_ms.push(ms);
                                }
                                break;
                            }
                            EventFrame::Error { reason, .. } => {
                                if reason.as_deref().is_some_and(|r| r.starts_with("shed")) {
                                    rep.shed += 1;
                                } else {
                                    rep.errors += 1;
                                }
                                break;
                            }
                            EventFrame::Started { .. }
                            | EventFrame::Stats(_)
                            | EventFrame::FleetStats(_) => {}
                        }
                    }
                }
                Ok(rep)
            };
            tx.send(run()).unwrap();
        });
    }
    drop(tx);

    let mut ttfts: Vec<f64> = Vec::new();
    let (mut tokens, mut completed, mut shed, mut errors) = (0usize, 0usize, 0usize, 0usize);
    while let Ok(r) = rx.recv() {
        let rep = r?;
        ttfts.extend(rep.ttfts_ms);
        tokens += rep.tokens;
        completed += rep.completed;
        shed += rep.shed;
        errors += rep.errors;
    }
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Release);
    let _ = driver.join();
    let fs = fleet.stats();
    let _ = sd_tx.send(());
    server.join().expect("server thread")?;
    let report = join.join();
    anyhow::ensure!(
        report.panicked_threads == 0 && report.unjoined_threads == 0,
        "engine threads misbehaved at shutdown: {} panicked, {} unjoined",
        report.panicked_threads,
        report.unjoined_threads
    );
    let per_replica = report.per_replica;

    anyhow::ensure!(errors == 0, "{errors} non-shed request errors under load");
    let issued = conns * reqs_per_conn;
    anyhow::ensure!(completed + shed == issued, "lost requests: {completed}+{shed} != {issued}");

    ttfts.sort_by(|a, b| a.partial_cmp(b).expect("no NaN ttft"));
    let pct = |p: f64| -> f64 {
        if ttfts.is_empty() {
            return 0.0;
        }
        ttfts[((ttfts.len() - 1) as f64 * p) as usize]
    };
    let (p50, p95, p99) = (pct(0.50), pct(0.95), pct(0.99));
    let decode_tokens: u64 = per_replica.iter().map(|s| s.decode_tokens).sum();
    let tps = decode_tokens as f64 / wall;
    let shed_rate = shed as f64 / issued as f64;
    let affinity_rate = fs.affinity_hits as f64 / fs.sessions_routed.max(1) as f64;

    println!("traffic: {issued} requests over {conns} conns in {wall:.2}s");
    println!("  completed {completed}, shed {shed} ({:.1}%)", shed_rate * 100.0);
    println!("  saturation decode: {tps:.0} tok/s across {replicas} replicas");
    println!("  TTFT p50 {p50:.1} ms, p95 {p95:.1} ms, p99 {p99:.1} ms");
    println!(
        "  router: {} routed ({:.0}% affinity), {} migrations ({} failed)",
        fs.sessions_routed,
        affinity_rate * 100.0,
        fs.migrations,
        fs.migration_failed
    );
    for (i, s) in per_replica.iter().enumerate() {
        println!(
            "  replica {i}: {} completed, {} decode tokens, {} in / {} out migrations",
            s.requests_completed, s.decode_tokens, s.migrated_in, s.migrated_out
        );
    }

    let j = Json::obj(vec![
        ("bench", Json::str("native_fleet")),
        ("preset", Json::str(&preset)),
        ("replicas", Json::num(replicas as f64)),
        ("conns", Json::num(conns as f64)),
        ("reqs_per_conn", Json::num(reqs_per_conn as f64)),
        ("wall_s", Json::num(wall)),
        ("requests_issued", Json::num(issued as f64)),
        ("requests_completed", Json::num(completed as f64)),
        ("requests_shed", Json::num(shed as f64)),
        ("shed_rate", Json::num(shed_rate)),
        ("client_tokens", Json::num(tokens as f64)),
        ("decode_tok_s", Json::num(tps)),
        ("ttft_ms_p50", Json::num(p50)),
        ("ttft_ms_p95", Json::num(p95)),
        ("ttft_ms_p99", Json::num(p99)),
        ("sessions_routed", Json::num(fs.sessions_routed as f64)),
        ("affinity_rate", Json::num(affinity_rate)),
        ("migrations", Json::num(fs.migrations as f64)),
        ("migration_failed", Json::num(fs.migration_failed as f64)),
        ("shed_queue_full", Json::num(fs.shed_queue_full as f64)),
        ("shed_deadline", Json::num(fs.shed_deadline as f64)),
    ]);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");
    println!("fleetbench OK");
    Ok(())
}
