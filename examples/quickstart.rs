//! Quickstart: the smallest end-to-end loop through the whole stack.
//!
//!   1. pick a backend (native pure-rust by default; PJRT artifacts when
//!      built with `--features pjrt` and `make artifacts` has run)
//!   2. train 30 TBPTT windows on a synthetic wiki-like byte corpus
//!   3. evaluate, then generate a few bytes with the linear-time sampler
//!
//! Run:  cargo run --release --example quickstart
//! (no artifacts, python, or HLO required — the native backend ships in-crate)

use anyhow::Result;
use transformer_vq::config::TrainConfig;
use transformer_vq::rng::Rng;
use transformer_vq::runtime::auto_backend;
use transformer_vq::sample::{SampleParams, Sampler};
use transformer_vq::tokenizer::{ByteTokenizer, Tokenizer};
use transformer_vq::train::run_training;

fn main() -> Result<()> {
    let backend = auto_backend(transformer_vq::artifacts_dir())?;
    println!("platform: {}", backend.platform());

    // --- train -----------------------------------------------------------
    let mut cfg = TrainConfig::quickstart();
    cfg.steps = 30;
    cfg.run_dir = std::path::PathBuf::from("runs/quickstart-example");
    let (_trainer, summary) = run_training(backend.as_ref(), &cfg)?;
    println!(
        "trained {} steps: loss {:.3} -> {:.3} ({:.3} bpb)",
        summary.steps,
        summary.loss_curve.first().map(|x| x.1).unwrap_or(f32::NAN),
        summary.final_loss,
        summary.final_bpb,
    );
    assert!(
        summary.final_loss < summary.loss_curve[0].1,
        "loss did not decrease"
    );
    // run_training leaves the final checkpoint (with the batcher position
    // for stream-exact resume) at <run_dir>/ckpt-final
    let ckpt = cfg.run_dir.join("ckpt-final");

    // --- sample ----------------------------------------------------------
    let mut sampler = Sampler::new(backend.as_ref(), "quickstart")?;
    sampler.load_weights(ckpt.join("state.tvq"))?;
    let tok = ByteTokenizer;
    let prompt: Vec<i32> = tok.encode(b"the ").into_iter().map(i32::from).collect();
    let prompts = vec![prompt; sampler.batch_size()];
    let mut rng = Rng::new(0);
    let outs = sampler.generate(&prompts, 48, SampleParams::default(), &mut rng)?;
    let bytes: Vec<u16> = outs[0].iter().map(|&t| t as u16).collect();
    println!("sample: the {}", String::from_utf8_lossy(&tok.decode(&bytes)));
    println!("quickstart OK");
    Ok(())
}
