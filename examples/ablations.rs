//! Regenerate paper Tables 1-2: codebook-size ablation and compressive-cache
//! ablation. Trains each ablation preset for a few hundred steps on the
//! enwik8 stand-in corpus and reports validation BPB + relative step latency
//! in the paper's table format.
//!
//! Paper's S values {256, 512, 1024} scale to {32, 64, 128} here (model is
//! ~100x smaller); the *trend* (BPB falls, latency rises with S; removing
//! the cache is faster but clearly worse) is the reproduction target.
//!
//! Usage: cargo run --release --example ablations -- [steps]

use anyhow::Result;
use transformer_vq::bench::Table;
use transformer_vq::paperbench::ablation_tables;
use transformer_vq::runtime::auto_backend;

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(200);
    let backend = auto_backend(transformer_vq::artifacts_dir())?;
    eprintln!("backend: {}", backend.platform());

    eprintln!("== Table 1 analogue: codebook size ablation ({steps} steps each)");
    let rows = ablation_tables(
        backend.as_ref(),
        &["ablate-S32", "ablate-S64", "ablate-S128"],
        "ablate-S64", // paper normalizes latency to the middle size
        steps,
    )?;
    let mut t = Table::new(&["Setting", "Val. BPB", "Latency (Rel.)"]);
    for r in &rows {
        let s = r.setting.trim_start_matches("ablate-");
        t.row(vec![format!("{s} (paper S={})", scale_s(s)),
                   format!("{:.4}", r.val_bpb),
                   format!("{:.3}", r.latency_rel)]);
    }
    t.print();

    eprintln!("\n== Table 2 analogue: compressive cache ablation");
    let rows = ablation_tables(
        backend.as_ref(),
        &["ablate-nocache", "ablate-cache"],
        "ablate-cache",
        steps,
    )?;
    let mut t = Table::new(&["Compressive cache", "Val. BPB", "Latency (Rel.)"]);
    for r in &rows {
        let name = if r.setting.contains("nocache") { "No" } else { "Yes" };
        t.row(vec![name.into(), format!("{:.4}", r.val_bpb),
                   format!("{:.3}", r.latency_rel)]);
    }
    t.print();
    println!("\npaper shape check: BPB should fall with S; 'No cache' should be");
    println!("faster per step but measurably worse in BPB (Tables 1-2).");
    Ok(())
}

fn scale_s(s: &str) -> usize {
    // our S values are the paper's divided by 8
    s.trim_start_matches('S').parse::<usize>().map(|x| x * 8).unwrap_or(0)
}
