//! Linear-time sampling demo (paper Fig. 4/5 analogue: generated samples).
//!
//! Loads a checkpoint produced by train_lm/quickstart and generates
//! continuations with nucleus sampling at two nucleus settings (the paper
//! contrasts nucleus 0.8 vs ~1.0). Per-token cost is O(S + 2L): constant in
//! how much has been generated.
//!
//! Usage: cargo run --release --example generate -- [preset] [ckpt_dir] [n]

use std::time::Instant;

use anyhow::Result;
use transformer_vq::rng::Rng;
use transformer_vq::runtime::auto_backend;
use transformer_vq::sample::{SampleParams, Sampler};
use transformer_vq::tokenizer::{ByteTokenizer, Tokenizer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("quickstart");
    let default_ckpt = format!("runs/train_lm-{preset}/ckpt-final");
    let ckpt = args.get(1).map(String::as_str).unwrap_or(&default_ckpt);
    let n_tokens: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(160);

    let backend = auto_backend(transformer_vq::artifacts_dir())?;
    eprintln!("backend: {}", backend.platform());
    let mut sampler = Sampler::new(backend.as_ref(), preset)?;
    let ckpt_path = std::path::Path::new(ckpt).join("state.tvq");
    if ckpt_path.exists() {
        sampler.load_weights(&ckpt_path)?;
        eprintln!("loaded weights from {}", ckpt_path.display());
    } else {
        eprintln!("WARNING: no checkpoint at {} — sampling untrained weights",
                  ckpt_path.display());
    }

    let tok = ByteTokenizer;
    let prompt = "the ";
    let prompt_ids: Vec<i32> =
        tok.encode(prompt.as_bytes()).into_iter().map(i32::from).collect();
    let b = sampler.batch_size();
    eprintln!(
        "session path: prompts ingest via chunked prefill ({} tokens/executor call), \
         then all {b} slots decode together",
        sampler.prefill_chunk()
    );

    for top_p in [0.8f32, 0.999] {
        let mut rng = Rng::new(42);
        let t0 = Instant::now();
        let outs = sampler.generate(
            &vec![prompt_ids.clone(); b],
            n_tokens,
            SampleParams { temperature: 1.0, top_p },
            &mut rng,
        )?;
        let dt = t0.elapsed();
        let total = b * (n_tokens + prompt_ids.len() - 1);
        println!(
            "\n=== nucleus {top_p} ({} tokens in {:.2?}, {:.0} tok/s) ===",
            total, dt, total as f64 / dt.as_secs_f64()
        );
        for (i, o) in outs.iter().take(2).enumerate() {
            let bytes: Vec<u16> = o.iter().map(|&t| t as u16).collect();
            println!(
                "--- sample {i} ---\n{prompt}{}",
                String::from_utf8_lossy(&tok.decode(&bytes))
            );
        }
    }
    Ok(())
}
