//! End-to-end training driver (DESIGN.md §4, Tables 3/4/5 analogues).
//!
//! Trains a Transformer-VQ preset on its synthetic corpus stand-in for a few
//! hundred TBPTT windows, logs the loss curve to <run_dir>/train.csv, then
//! reports the paper's quality metric on the held-out test split:
//! bits-per-byte for the byte tracks, word-level perplexity for the
//! open-vocabulary (PG-19-like) track.
//!
//! Usage:
//!   cargo run --release --example train_lm -- [preset] [steps]
//!   preset in {enwik8-tiny, pg19-tiny, imagenet64-tiny, quickstart,
//!              enwik8-tiny-full}

use anyhow::Result;
use transformer_vq::config::TrainConfig;
use transformer_vq::data::{build_corpus, zipf, TbpttBatcher};
use transformer_vq::metrics::nats_to_bpb;
use transformer_vq::runtime::auto_backend;
use transformer_vq::train::run_training;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("enwik8-tiny");
    let steps: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(300);

    let backend = auto_backend(transformer_vq::artifacts_dir())?;
    let mut cfg = TrainConfig::preset(preset, steps)?;
    cfg.run_dir = std::path::PathBuf::from(format!("runs/train_lm-{preset}"));
    eprintln!(
        "training {preset} for {steps} steps on {} ({} tokens, {} backend)",
        cfg.corpus,
        cfg.corpus_tokens,
        backend.platform()
    );
    let (trainer, summary) = run_training(backend.as_ref(), &cfg)?;

    // --- test-split quality metric (the paper's Tables 3/4/5 numbers) -----
    let corpus = build_corpus(&cfg.corpus, cfg.corpus_tokens, cfg.seed)?;
    let (_, _, test_c) = corpus.split();
    let n_words = zipf::word_count(&test_c.tokens);
    let n_tokens = test_c.len();
    let mut test_batcher =
        TbpttBatcher::new(test_c.tokens, trainer.batch_size(), trainer.window_len())?;
    let windows = (test_batcher.windows_per_epoch()).min(64);
    let ce = trainer.evaluate(&mut test_batcher, windows)?;

    println!("== {preset} results after {steps} steps ==");
    println!("final train loss: {:.4}", summary.final_loss);
    println!("test CE:          {ce:.4} nats/token");
    println!("test BPB:         {:.4}", nats_to_bpb(ce));
    if preset.starts_with("pg19") {
        // Rae et al. (2020) conversion: total nats over the span divided by
        // the whitespace word count (Table 4's metric)
        let wlp =
            transformer_vq::metrics::word_level_perplexity(ce * n_tokens as f64, n_words);
        println!("test WLP:         {wlp:.2}  ({n_words} words / {n_tokens} tokens)");
    }
    if let Some(tps) = summary.tokens_per_sec {
        println!("throughput:       {tps:.0} tokens/sec");
    }
    println!("loss curve -> {}/train.csv", cfg.run_dir.display());
    Ok(())
}
