//! Native train-throughput baseline: tokens/sec of the full §3.4.2 update
//! (forward + exact backprop through the Theorem 3.7 block recurrence +
//! Adam + EMA codebook learning) on a synthetic corpus.
//!
//! Complements `perfbench` (decode flat-latency): together CI tracks both
//! the serving and the training side of the linear-time claim. Emits
//! `BENCH_native_train.json` so the trajectory is visible across PRs.
//!
//! Also reports the identity-keyed weight-cache effect: steps/sec with the
//! executor's parsed-weight cache warm (steady-state training) versus a
//! fresh executor per step (every step re-parses the params group).
//!
//! Usage: cargo run --release --example trainbench -- [preset] [steps] [out.json]

use anyhow::Result;
use transformer_vq::data::TbpttBatcher;
use transformer_vq::json::Json;
use transformer_vq::native::NativeBackend;
use transformer_vq::runtime::Backend;
use transformer_vq::schedule::LrSchedule;
use transformer_vq::train::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("quickstart");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(60);
    let out_path = args
        .get(2)
        .map(String::as_str)
        .unwrap_or("BENCH_native_train.json");

    let backend = NativeBackend::new();
    let mut trainer = Trainer::new(&backend, preset, LrSchedule::constant(3e-3))?;
    let (b, w) = (trainer.batch_size(), trainer.window_len());
    eprintln!("trainbench: {preset}.train  (B={b}, W={w}, {steps} steps)");
    let corpus = transformer_vq::data::build_corpus("markov", 200_000, 0)?;
    let mut batcher = TbpttBatcher::new(corpus.tokens, b, w)?;

    // warmup (first step parses weights; later steps hit the cache)
    let mut first_loss = f32::NAN;
    for _ in 0..3 {
        first_loss = trainer.train_on(&batcher.next_batch())?.loss;
    }

    let t0 = std::time::Instant::now();
    let mut last_loss = first_loss;
    for _ in 0..steps {
        last_loss = trainer.train_on(&batcher.next_batch())?.loss;
    }
    let dt = t0.elapsed().as_secs_f64();
    let tokens = (steps * b * w) as f64;
    let tok_per_sec = tokens / dt;
    let ms_per_step = dt * 1e3 / steps as f64;
    println!(
        "{steps} steps in {dt:.2}s: {tok_per_sec:.0} tok/s  ({ms_per_step:.1} ms/step)  \
         loss {first_loss:.3} -> {last_loss:.3}"
    );

    // cold-executor comparison: a fresh executor per step defeats the
    // identity-keyed weight cache, so every step re-parses params+cb.
    // Executors are constructed before the clock starts so only the
    // parse cost is in the measured region.
    let cold_steps = steps.clamp(1, 20);
    let mut cold_exes = Vec::with_capacity(cold_steps);
    for _ in 0..cold_steps {
        cold_exes.push(backend.load(&format!("{preset}.train"))?);
    }
    let t1 = std::time::Instant::now();
    for exe in cold_exes {
        trainer.exe_train = exe;
        trainer.train_on(&batcher.next_batch())?;
    }
    let cold_dt = t1.elapsed().as_secs_f64();
    let cold_tok_per_sec = (cold_steps * b * w) as f64 / cold_dt;
    println!(
        "weight cache: warm {tok_per_sec:.0} tok/s vs cold-parse {cold_tok_per_sec:.0} tok/s \
         ({:.2}x)",
        tok_per_sec / cold_tok_per_sec
    );

    let j = Json::obj(vec![
        ("bench", Json::str("native_train")),
        ("preset", Json::str(preset)),
        ("batch", Json::num(b as f64)),
        ("window", Json::num(w as f64)),
        ("steps", Json::num(steps as f64)),
        ("tokens_per_sec", Json::num(tok_per_sec)),
        ("ms_per_step", Json::num(ms_per_step)),
        ("tokens_per_sec_cold_parse", Json::num(cold_tok_per_sec)),
        ("first_loss", Json::num(first_loss as f64)),
        ("last_loss", Json::num(last_loss as f64)),
    ]);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");

    assert!(
        last_loss.is_finite() && last_loss < first_loss,
        "training regressed: loss {first_loss} -> {last_loss}"
    );
    println!("trainbench OK: full-model training is live and converging");
    Ok(())
}
