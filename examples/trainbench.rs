//! Native train-throughput baseline: tokens/sec of the full §3.4.2 update
//! (forward + exact backprop through the Theorem 3.7 block recurrence +
//! Adam + EMA codebook learning) on a synthetic corpus.
//!
//! Complements `perfbench` (decode flat-latency): together CI tracks both
//! the serving and the training side of the linear-time claim. Emits
//! `BENCH_native_train.json` so the trajectory is visible across PRs.
//!
//! Also reports:
//! * the identity-keyed weight-cache effect: steps/sec with the executor's
//!   parsed-weight cache warm versus a fresh executor per step, and
//! * the thread-scaling curve: tok/s at num_threads = 1/2/4/N over TBPTT
//!   windows of 512 and 2048 tokens (batch lanes run one per pool thread;
//!   metrics are bit-identical across thread counts, only wall time moves).
//!
//! See DESIGN.md §7 for how to read the emitted JSON.
//!
//! Usage: cargo run --release --example trainbench -- [preset] [steps] [out.json]

use anyhow::Result;
use transformer_vq::data::TbpttBatcher;
use transformer_vq::json::Json;
use transformer_vq::native::{kernels, preset_config, NativeBackend, NativeOptions};
use transformer_vq::runtime::Backend;
use transformer_vq::schedule::LrSchedule;
use transformer_vq::train::Trainer;

/// tok/s of `timed_steps` train steps of `preset`'s model at window
/// length `seq` and thread budget `nt` (1 warmup step first, so weight
/// parsing is out of the measured region).
fn sweep_point(
    preset: &str,
    corpus_tokens: &[u16],
    seq: usize,
    nt: usize,
    timed_steps: usize,
) -> Result<f64> {
    let mut cfg = preset_config(preset)?;
    cfg.window_len = seq;
    let name = format!("bench-{preset}-seq{seq}");
    let backend = NativeBackend::with_preset(&name, cfg, 0x5EED)
        .with_options(NativeOptions::with_threads(nt));
    let mut trainer = Trainer::new(&backend, &name, LrSchedule::constant(1e-3))?;
    let (b, w) = (trainer.batch_size(), trainer.window_len());
    let mut batcher = TbpttBatcher::new(corpus_tokens.to_vec(), b, w)?;
    trainer.train_on(&batcher.next_batch())?;
    let t0 = std::time::Instant::now();
    for _ in 0..timed_steps {
        trainer.train_on(&batcher.next_batch())?;
    }
    Ok((timed_steps * b * w) as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("quickstart");
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(60);
    let out_path = args
        .get(2)
        .map(String::as_str)
        .unwrap_or("BENCH_native_train.json");

    let backend = NativeBackend::new();
    let mut trainer = Trainer::new(&backend, preset, LrSchedule::constant(3e-3))?;
    let (b, w) = (trainer.batch_size(), trainer.window_len());
    eprintln!("trainbench: {preset}.train  (B={b}, W={w}, {steps} steps)");
    let corpus = transformer_vq::data::build_corpus("markov", 200_000, 0)?;
    let mut batcher = TbpttBatcher::new(corpus.tokens, b, w)?;

    // warmup (first step parses weights; later steps hit the cache)
    let mut first_loss = f32::NAN;
    for _ in 0..3 {
        first_loss = trainer.train_on(&batcher.next_batch())?.loss;
    }

    let t0 = std::time::Instant::now();
    let mut last_loss = first_loss;
    for _ in 0..steps {
        last_loss = trainer.train_on(&batcher.next_batch())?.loss;
    }
    let dt = t0.elapsed().as_secs_f64();
    let tokens = (steps * b * w) as f64;
    let tok_per_sec = tokens / dt;
    let ms_per_step = dt * 1e3 / steps as f64;
    println!(
        "{steps} steps in {dt:.2}s: {tok_per_sec:.0} tok/s  ({ms_per_step:.1} ms/step)  \
         loss {first_loss:.3} -> {last_loss:.3}"
    );

    // cold-executor comparison: a fresh executor per step defeats the
    // identity-keyed weight cache, so every step re-parses params+cb.
    // Executors are constructed before the clock starts so only the
    // parse cost is in the measured region.
    let cold_steps = steps.clamp(1, 20);
    let mut cold_exes = Vec::with_capacity(cold_steps);
    for _ in 0..cold_steps {
        cold_exes.push(backend.load(&format!("{preset}.train"))?);
    }
    let t1 = std::time::Instant::now();
    for exe in cold_exes {
        trainer.exe_train = exe;
        trainer.train_on(&batcher.next_batch())?;
    }
    let cold_dt = t1.elapsed().as_secs_f64();
    let cold_tok_per_sec = (cold_steps * b * w) as f64 / cold_dt;
    println!(
        "weight cache: warm {tok_per_sec:.0} tok/s vs cold-parse {cold_tok_per_sec:.0} tok/s \
         ({:.2}x)",
        tok_per_sec / cold_tok_per_sec
    );

    // thread-scaling sweep: Linformer-style fixed-budget tok/s curves at
    // window lengths 512 / 2048 across 1/2/4/N threads
    let ncores = kernels::default_threads();
    let mut thread_counts = vec![1usize, 2, 4, ncores];
    thread_counts.sort_unstable();
    thread_counts.dedup();
    let seqs = [512usize, 2048];
    let mut scaling: Vec<(usize, usize, f64)> = Vec::new();
    println!("\nthread scaling ({preset} model, {ncores} cores):");
    println!("{:>9} {:>7} {:>11}", "threads", "seq", "tok/s");
    // one corpus for the whole sweep; each point only re-windows it
    let sweep_corpus = transformer_vq::data::build_corpus("markov", 200_000, 1)?;
    for &seq in &seqs {
        for &nt in &thread_counts {
            let tps = sweep_point(preset, &sweep_corpus.tokens, seq, nt, 2)?;
            println!("{nt:>9} {seq:>7} {tps:>11.0}");
            scaling.push((nt, seq, tps));
        }
    }
    let speedup_4t = {
        let at = |nt: usize| scaling.iter().find(|(n, s, _)| *n == nt && *s == 2048);
        match (at(1), at(4)) {
            (Some((_, _, t1s)), Some((_, _, t4s))) => Some(t4s / t1s),
            _ => None,
        }
    };
    if let Some(s) = speedup_4t {
        println!("speedup at 4 threads (seq 2048): {s:.2}x");
    }

    let mut fields = vec![
        ("bench", Json::str("native_train")),
        ("preset", Json::str(preset)),
        ("batch", Json::num(b as f64)),
        ("window", Json::num(w as f64)),
        ("steps", Json::num(steps as f64)),
        ("tokens_per_sec", Json::num(tok_per_sec)),
        ("ms_per_step", Json::num(ms_per_step)),
        ("tokens_per_sec_cold_parse", Json::num(cold_tok_per_sec)),
        ("first_loss", Json::num(first_loss as f64)),
        ("last_loss", Json::num(last_loss as f64)),
        ("cores", Json::num(ncores as f64)),
        (
            "thread_scaling",
            Json::Arr(
                scaling
                    .iter()
                    .map(|(nt, seq, tps)| {
                        Json::obj(vec![
                            ("threads", Json::num(*nt as f64)),
                            ("seq", Json::num(*seq as f64)),
                            ("tokens_per_sec", Json::num(*tps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(s) = speedup_4t {
        fields.push(("speedup_threads4_vs_1_seq2048", Json::num(s)));
    }
    let j = Json::obj(fields);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");

    assert!(
        last_loss.is_finite() && last_loss < first_loss,
        "training regressed: loss {first_loss} -> {last_loss}"
    );
    println!("trainbench OK: full-model training is live and converging");
    Ok(())
}
