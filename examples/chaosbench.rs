//! Chaos bench for the self-healing fleet (DESIGN.md §12): Zipf traffic
//! under continuous deterministic fault injection, with a hard identity
//! gate against a fault-free reference run.
//!
//! Phase 1 computes the reference: a fixed-seed request set runs through
//! one bare engine with no faults attached — its outputs are the ground
//! truth every chaos outcome is compared against.
//!
//! Phase 2 replays the same requests against a supervised fleet with a
//! [`FaultPlan`] live: replicas crash and stall at token boundaries,
//! migrations drop or corrupt snapshots in transit, and a rebalance
//! driver keeps sessions moving under fire. The gate is absolute — every
//! session either completes **bit-identical** to the reference or fails
//! with a *typed* reason (shed, `replica_lost`, mid-migration loss, or
//! detected snapshot corruption); any token mismatch, untyped error, or
//! stream that stops making progress (per-event timeout) fails the bench.
//! A forced-crash drill then pins the headline robustness claim: a
//! mid-stream session whose replica is killed resumes from its vault
//! snapshot on a survivor and still matches the reference exactly, and
//! the supervisor's restart/recovery counters prove the self-healing
//! actually ran.
//!
//! Phase 3 tortures checkpoint I/O: a real trainer saves under injected
//! write/sync/rename failures, and after every failed save a fresh
//! trainer must still load the last good checkpoint.
//!
//! Emits `BENCH_native_chaos.json` (path overridable) — the fifth CI
//! perf artifact, next to decode/train/serve/fleet.
//!
//! Usage: cargo run --release --example chaosbench --
//!        [preset] [replicas] [sessions] [faults_spec] [out.json]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;
use transformer_vq::coordinator::{Engine, Frontend, GenEvent, GenRequest, RequestEvents};
use transformer_vq::data::{TbpttBatcher, ZipfLengths, ZipfSampler};
use transformer_vq::fleet::{
    FaultPlan, Fleet, FleetHandle, FleetOptions, Supervisor, SupervisorOptions,
};
use transformer_vq::json::Json;
use transformer_vq::native::NativeBackend;
use transformer_vq::rng::Rng;
use transformer_vq::sample::{SampleParams, Sampler};
use transformer_vq::schedule::LrSchedule;
use transformer_vq::train::{load_checkpoint, save_checkpoint, save_checkpoint_with, Trainer};

/// Per-event progress bound: a stream that takes longer than this between
/// events is declared hung, and a hang fails the bench.
const EVENT_TIMEOUT: Duration = Duration::from_secs(60);

/// Deterministic 32-prompt pool, ordered hot-first.
fn prompt_pool() -> Vec<String> {
    (0..32)
        .map(|i| {
            let stem = match i % 4 {
                0 => "the cache holds",
                1 => "attention over codes",
                2 => "linear time decode",
                _ => "quantized keys",
            };
            format!("{stem} #{i:02} ")
        })
        .collect()
}

fn req(prompt: &str, max_tokens: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: prompt.bytes().map(i32::from).collect(),
        max_tokens,
        params: SampleParams::default(),
        seed: Some(seed),
        ..GenRequest::default()
    }
}

/// One deterministic traffic case: fixed prompt, length, and sampling
/// seed, so the fault-free and chaos runs issue byte-identical requests.
struct Case {
    prompt: String,
    max_tokens: usize,
    seed: u64,
}

fn build_cases(n: usize) -> Result<Vec<Case>> {
    let pool = prompt_pool();
    let mut rng = Rng::new(0xC4A0_5EED);
    let popularity = ZipfSampler::new(pool.len(), 1.1)?;
    let lengths = ZipfLengths::new(8, 48, 1.2)?;
    Ok((0..n)
        .map(|i| Case {
            prompt: pool[popularity.sample(&mut rng)].clone(),
            max_tokens: lengths.sample(&mut rng),
            seed: 5000 + i as u64,
        })
        .collect())
}

/// The long-running request used by the forced-crash drill.
fn drill_req() -> GenRequest {
    req(&prompt_pool()[0], 96, 4242)
}

/// Phase 1: run every case (and the drill request) through one bare,
/// fault-free engine to get the reference token streams.
fn reference_outputs(preset: &str, cases: &[Case]) -> Result<(Vec<Vec<i32>>, Vec<i32>)> {
    let preset_c = preset.to_string();
    let (engine, ejoin) =
        Engine::spawn(move || Sampler::new(&NativeBackend::new(), &preset_c), 42)?;
    let mut want = Vec::new();
    for c in cases {
        let rh = engine
            .submit(req(&c.prompt, c.max_tokens, c.seed))
            .map_err(|e| anyhow::anyhow!(e))?;
        want.push(rh.wait_outcome().map_err(|e| anyhow::anyhow!(e))?.tokens);
    }
    let rh = engine.submit(drill_req()).map_err(|e| anyhow::anyhow!(e))?;
    let drill = rh.wait_outcome().map_err(|e| anyhow::anyhow!(e))?.tokens;
    engine.shutdown();
    let _ = ejoin.join();
    Ok((want, drill))
}

/// Typed failure taxonomy for chaos outcomes. Anything not in this enum
/// (plus bit-identical completion) fails the bench.
#[derive(Default)]
struct WorkerReport {
    completed: usize,
    /// Session completed but tokens diverged from the reference — fatal.
    mismatches: Vec<usize>,
    /// Typed `replica_lost` / mid-migration losses.
    lost_typed: usize,
    /// Target detected a corrupted in-transit snapshot (checksum trip).
    corruption_detected: usize,
    shed: usize,
    /// Untyped stream errors — fatal.
    untyped: Vec<(usize, String)>,
    /// Streams that stopped making progress — fatal.
    hangs: Vec<usize>,
}

fn typed_loss(e: &str) -> bool {
    e.starts_with("replica_lost") || e.contains("mid-migration")
}

fn corruption(e: &str) -> bool {
    // target replica's checksum verification caught the flipped byte and
    // surfaced a clean per-request error instead of silent corruption
    e.starts_with("restore migrated slot")
}

/// Drive one case against the fleet and classify the outcome.
fn run_case(fleet: &FleetHandle, ix: usize, c: &Case, want: &[i32], rep: &mut WorkerReport) {
    let rh = match fleet.submit_session(&format!("chaos-{ix}"), req(&c.prompt, c.max_tokens, c.seed))
    {
        Ok(rh) => rh,
        Err(_) => {
            // submit-time refusals are always typed (shed / duplicate /
            // no live replica) — admission control doing its job
            rep.shed += 1;
            return;
        }
    };
    let mut got: Vec<i32> = Vec::new();
    loop {
        match rh.recv_event_timeout(EVENT_TIMEOUT) {
            Ok(Some(GenEvent::Delta { token, .. })) => got.push(token),
            Ok(Some(GenEvent::Done(o))) => {
                // the streamed deltas must also agree with the final
                // tokens: recovery replays may never duplicate or skip
                if o.tokens == want && got == o.tokens {
                    rep.completed += 1;
                } else {
                    rep.mismatches.push(ix);
                }
                return;
            }
            Ok(Some(GenEvent::Error(e))) => {
                if typed_loss(&e) {
                    rep.lost_typed += 1;
                } else if corruption(&e) {
                    rep.corruption_detected += 1;
                } else {
                    rep.untyped.push((ix, e));
                }
                return;
            }
            Ok(Some(GenEvent::Started { .. })) => {}
            Ok(None) => {
                rep.hangs.push(ix);
                return;
            }
            Err(e) => {
                rep.untyped.push((ix, format!("stream dropped: {e}")));
                return;
            }
        }
    }
}

/// Forced-crash drill: submit a long request, wait until it has streamed
/// (so an armed-vault snapshot exists), kill its home replica, and require
/// the continuation to match the fault-free reference bit-for-bit with the
/// recovery visible in the fleet counters.
fn crash_drill(fleet: &FleetHandle, want: &[i32]) -> Result<()> {
    for attempt in 0..5 {
        let before = fleet.stats();
        let session = format!("drill-{attempt}");
        let rh = match fleet.submit_session(&session, drill_req()) {
            Ok(rh) => rh,
            Err(e) => anyhow::bail!("drill submit refused: {e:?}"),
        };
        let mut got: Vec<i32> = Vec::new();
        let mut crashed_at = None;
        let outcome = loop {
            match rh.recv_event_timeout(EVENT_TIMEOUT).map_err(|e| anyhow::anyhow!(e))? {
                Some(GenEvent::Delta { token, .. }) => {
                    got.push(token);
                    if crashed_at.is_none() && got.len() >= 2 {
                        // the vault holds a snapshot from the last token
                        // boundary — now kill the session's home replica
                        if let Some(home) = fleet.session_replica(&session) {
                            fleet.crash_replica(home).map_err(|e| anyhow::anyhow!(e))?;
                            crashed_at = Some(got.len());
                        }
                    }
                }
                Some(GenEvent::Done(o)) => break Some(o.tokens),
                Some(GenEvent::Error(e)) => {
                    anyhow::ensure!(
                        typed_loss(&e) || corruption(&e),
                        "drill attempt {attempt} died with an untyped error: {e}"
                    );
                    break None; // typed loss under a race — retry the drill
                }
                Some(GenEvent::Started { .. }) => {}
                None => anyhow::bail!("drill attempt {attempt} hung (no event in 60s)"),
            }
        };
        let Some(tokens) = outcome else { continue };
        anyhow::ensure!(tokens == want, "drill tokens diverged from fault-free reference");
        anyhow::ensure!(got == tokens, "drill deltas disagree with final tokens");
        // tokens that streamed well past the crash point can only have come
        // from a vault resume on a survivor; a near-end crash proves
        // nothing, so retry (the engine can emit at most ~1 in-flight
        // delta between crash() and the thread dying)
        let Some(n) = crashed_at else { continue };
        if tokens.len() <= n + 2 {
            continue;
        }
        // the supervisor's counters lag the stream by a poll interval or
        // two — wait for them rather than racing them
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let after = fleet.stats();
            if after.restarts > before.restarts
                && after.sessions_recovered > before.sessions_recovered
            {
                println!(
                    "drill: crash at token {n} survived on attempt {attempt}; \
                     {} tokens bit-identical after resume",
                    tokens.len()
                );
                return Ok(());
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "drill stream resumed but restart/recovery counters never moved"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    anyhow::bail!("forced-crash drill never observed a recovery in 5 attempts")
}

/// Phase 3: checkpoint torture. A real quickstart trainer advances and
/// saves under injected I/O faults; after *every* attempt — failed or not
/// — a fresh trainer must load the newest surviving checkpoint.
fn checkpoint_torture(preset: &str, plan: &FaultPlan) -> Result<Json> {
    let mut plan = plan.clone();
    if plan.ckpt_io <= 0.0 {
        plan.ckpt_io = 0.3; // the torture needs failures even if the
                            // traffic spec left checkpoint I/O clean
    }
    let mut inj = plan.injector(0xCC);

    let backend = NativeBackend::new();
    let lr = 1e-3f32;
    let mut trainer = Trainer::new(&backend, preset, LrSchedule::constant(lr))?;
    let corpus = transformer_vq::data::build_corpus("markov", 100_000, 0)?;
    let mut batcher =
        TbpttBatcher::new(corpus.tokens, trainer.batch_size(), trainer.window_len())?;
    let tmp = transformer_vq::testutil::TempDir::new();
    let dir = tmp.path();

    // baseline: one real step, one clean save — the last-good floor
    trainer.train_on(&batcher.next_batch())?;
    save_checkpoint(&trainer, &batcher, dir)?;
    let mut last_good = trainer.step;

    let (mut attempts, mut failures, mut loads_ok) = (0u64, 0u64, 0u64);
    for _ in 0..12 {
        trainer.train_on(&batcher.next_batch())?;
        attempts += 1;
        match save_checkpoint_with(&trainer, &batcher, dir, &mut inj) {
            Ok(()) => last_good = trainer.step,
            Err(e) => {
                let msg = format!("{e:#}");
                anyhow::ensure!(
                    msg.contains("injected ckpt_io fault"),
                    "non-injected save failure during torture: {msg}"
                );
                failures += 1;
            }
        }
        // the gate: no matter where the save died, a fresh trainer loads
        // the newest surviving checkpoint
        let mut probe = Trainer::new(&backend, preset, LrSchedule::constant(lr))?;
        let meta = load_checkpoint(&mut probe, None, dir)
            .map_err(|e| anyhow::anyhow!("checkpoint unloadable after injected fault: {e:#}"))?;
        anyhow::ensure!(
            meta.step >= last_good,
            "checkpoint went backwards: loaded step {} < last good {}",
            meta.step,
            last_good
        );
        loads_ok += 1;
    }
    anyhow::ensure!(failures >= 1, "torture injected no I/O faults — raise ckpt_io");
    anyhow::ensure!(loads_ok == attempts, "a reload failed after an injected fault");
    println!(
        "checkpoints: {attempts} torture saves ({failures} killed mid-write), \
         {loads_ok}/{attempts} reloads OK, last good step {last_good}"
    );
    Ok(Json::obj(vec![
        ("ckpt_attempts", Json::num(attempts as f64)),
        ("ckpt_injected_failures", Json::num(failures as f64)),
        ("ckpt_loads_ok", Json::num(loads_ok as f64)),
    ]))
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().cloned().unwrap_or_else(|| "quickstart".into());
    let replicas: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let sessions: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(48);
    let spec = args
        .get(3)
        .cloned()
        .unwrap_or_else(|| {
            "seed=7,crash=0.005,slow=0.02:2ms,drop_inject=0.1,corrupt_snapshot=0.05,ckpt_io=0.25"
                .into()
        });
    let out_path = args.get(4).map(String::as_str).unwrap_or("BENCH_native_chaos.json");
    anyhow::ensure!(replicas >= 2, "chaosbench needs at least 2 replicas");
    let plan = FaultPlan::parse(&spec).map_err(|e| anyhow::anyhow!(e))?;

    eprintln!("chaosbench: {preset}, {replicas} replicas, {sessions} sessions, faults [{spec}]");

    // --- phase 1: fault-free reference --------------------------------
    let cases = build_cases(sessions)?;
    let (want, drill_want) = reference_outputs(&preset, &cases)?;
    println!("reference: {} cases + drill recorded fault-free", cases.len());

    // --- phase 2: same traffic, faults on, supervisor attached --------
    let preset_c = preset.to_string();
    let opts = FleetOptions {
        replicas,
        queue_depth: 8,
        shed_deadline_ms: None,
        faults: Some(plan.clone()),
    };
    let (fleet, join) =
        Fleet::spawn(opts, move |_replica| Sampler::new(&NativeBackend::new(), &preset_c), 42)?;
    let supervisor = Supervisor::attach(
        fleet.clone(),
        SupervisorOptions {
            poll: Duration::from_millis(5),
            heartbeat_timeout: Duration::from_millis(500),
            // at a 5ms poll the default threshold would declare a busy
            // replica wedged after 15ms without a token — give it 200ms
            wedge_after: 40,
            stop_grace: Duration::from_millis(250),
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(50),
            seed: plan.seed,
            ..SupervisorOptions::default()
        },
    );
    // rebalance driver: live migrations under fire, which is what feeds
    // the drop_inject / corrupt_snapshot seams
    let stop = Arc::new(AtomicBool::new(false));
    let driver = {
        let fleet = fleet.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let _ = fleet.rebalance();
                std::thread::sleep(Duration::from_millis(10));
            }
        })
    };

    let cases = Arc::new(cases);
    let want = Arc::new(want);
    let t0 = Instant::now();
    let workers = 8usize.min(sessions.max(1));
    let (tx, rx) = mpsc::channel();
    for w in 0..workers {
        let fleet = fleet.clone();
        let cases = Arc::clone(&cases);
        let want = Arc::clone(&want);
        let tx = tx.clone();
        std::thread::spawn(move || {
            let mut rep = WorkerReport::default();
            let mut ix = w;
            while ix < cases.len() {
                run_case(&fleet, ix, &cases[ix], &want[ix], &mut rep);
                ix += workers;
            }
            tx.send(rep).unwrap();
        });
    }
    drop(tx);

    let mut total = WorkerReport::default();
    while let Ok(rep) = rx.recv() {
        total.completed += rep.completed;
        total.mismatches.extend(rep.mismatches);
        total.lost_typed += rep.lost_typed;
        total.corruption_detected += rep.corruption_detected;
        total.shed += rep.shed;
        total.untyped.extend(rep.untyped);
        total.hangs.extend(rep.hangs);
    }

    crash_drill(&fleet, &drill_want)?;
    let wall = t0.elapsed().as_secs_f64();

    stop.store(true, Ordering::Release);
    let _ = driver.join();
    let fs = fleet.stats();
    let sup = supervisor.stop();
    fleet.shutdown_all();
    let report = join.join();

    // --- the identity gate --------------------------------------------
    anyhow::ensure!(
        total.mismatches.is_empty(),
        "{} sessions completed with WRONG tokens (cases {:?})",
        total.mismatches.len(),
        total.mismatches
    );
    anyhow::ensure!(
        total.untyped.is_empty(),
        "untyped failures under chaos: {:?}",
        total.untyped
    );
    anyhow::ensure!(total.hangs.is_empty(), "hung streams under chaos: {:?}", total.hangs);
    let accounted =
        total.completed + total.lost_typed + total.corruption_detected + total.shed;
    anyhow::ensure!(
        accounted == sessions,
        "lost track of sessions: {accounted} accounted != {sessions} issued"
    );
    anyhow::ensure!(total.completed >= sessions / 2, "chaos killed most traffic — plan too hot");
    anyhow::ensure!(sup.restarts >= 1, "no replica restart happened — chaos never bit");
    anyhow::ensure!(sup.sessions_recovered >= 1, "no snapshot-backed recovery happened");
    anyhow::ensure!(
        report.panicked_threads == 0 && report.unjoined_threads == 0,
        "engine threads misbehaved at shutdown: {} panicked, {} unjoined",
        report.panicked_threads,
        report.unjoined_threads
    );

    let mut rec = sup.recovery_ms.clone();
    rec.sort_by(|a, b| a.partial_cmp(b).expect("no NaN recovery time"));
    let (rp50, rp95) = (percentile(&rec, 0.50), percentile(&rec, 0.95));
    let rmax = rec.last().copied().unwrap_or(0.0);

    println!("chaos traffic: {sessions} sessions in {wall:.2}s under [{spec}]");
    println!(
        "  {} bit-identical, {} typed losses, {} corruptions detected, {} shed",
        total.completed, total.lost_typed, total.corruption_detected, total.shed
    );
    println!(
        "  supervisor: {} restarts ({} wedges); {} retried / {} recovered / {} lost",
        sup.restarts, sup.wedges, sup.sessions_retried, sup.sessions_recovered, sup.sessions_lost
    );
    println!("  recovery p50 {rp50:.1} ms, p95 {rp95:.1} ms, max {rmax:.1} ms");
    println!(
        "  router: {} migrations ({} failed), {} routed",
        fs.migrations, fs.migration_failed, fs.sessions_routed
    );

    // --- phase 3: checkpoint torture ----------------------------------
    let ckpt = checkpoint_torture(&preset, &plan)?;

    let j = Json::obj(vec![
        ("bench", Json::str("native_chaos")),
        ("preset", Json::str(&preset)),
        ("replicas", Json::num(replicas as f64)),
        ("sessions", Json::num(sessions as f64)),
        ("faults", Json::str(&spec)),
        ("wall_s", Json::num(wall)),
        ("completed_bit_identical", Json::num(total.completed as f64)),
        ("mismatches", Json::num(total.mismatches.len() as f64)),
        ("hangs", Json::num(total.hangs.len() as f64)),
        ("untyped_errors", Json::num(total.untyped.len() as f64)),
        ("typed_losses", Json::num(total.lost_typed as f64)),
        ("corruption_detected", Json::num(total.corruption_detected as f64)),
        ("shed", Json::num(total.shed as f64)),
        ("restarts", Json::num(sup.restarts as f64)),
        ("wedges", Json::num(sup.wedges as f64)),
        ("sessions_retried", Json::num(sup.sessions_retried as f64)),
        ("sessions_recovered", Json::num(sup.sessions_recovered as f64)),
        ("sessions_lost", Json::num(sup.sessions_lost as f64)),
        ("recovery_ms_p50", Json::num(rp50)),
        ("recovery_ms_p95", Json::num(rp95)),
        ("recovery_ms_max", Json::num(rmax)),
        ("migrations", Json::num(fs.migrations as f64)),
        ("migration_failed", Json::num(fs.migration_failed as f64)),
        ("ckpt_attempts", ckpt.get("ckpt_attempts").cloned().unwrap_or(Json::num(0.0))),
        (
            "ckpt_injected_failures",
            ckpt.get("ckpt_injected_failures").cloned().unwrap_or(Json::num(0.0)),
        ),
        ("ckpt_loads_ok", ckpt.get("ckpt_loads_ok").cloned().unwrap_or(Json::num(0.0))),
    ]);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");
    println!("chaosbench OK");
    Ok(())
}
