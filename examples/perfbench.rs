//! Native decode perf baseline: per-token latency vs sequence position,
//! plus the thread-scaling curve of the batch-lane-parallel engine.
//!
//! The paper's serving claim (Remark 3.8) is that VQ decode costs
//! O(S + 2L) per token — *independent of position*. This bench drives the
//! native backend's `<preset>.decode` executor for thousands of consecutive
//! positions without resetting, records per-step wall time, and reports
//! tokens/sec at exponentially spaced positions. A quadratic-cache model
//! would slow down linearly with position; this one must stay flat (the
//! last reported position — 8192 at the default max_pos — within 1.5x of
//! position 64, asserted).
//!
//! It then re-drives the same stream at num_threads = 1/2/4/N (Linformer-
//! style fixed-budget tok/s curves across sequence positions 512/2k/8k) so
//! CI tracks the multi-core speedup next to the flatness baseline. Logits
//! are bit-identical across thread counts (enforced by
//! rust/tests/parallel_determinism.rs); only the wall clock may differ.
//!
//! Finally it sweeps the PR-5 performance axes on `DecodeSession` (the
//! allocation-free stateful loop, no tensor round-trip): SIMD on vs off
//! at B = 1, and batched-lane vs per-lane decode at B ∈ {1, 4, 8}, each
//! reporting aggregate tok/s at positions 512/2k/8k. The headline ratios
//! (`simd_speedup`, `batched_speedup_b8`) are asserted *softly* — values
//! always land in the artifact, a miss prints a warning instead of
//! failing CI, since both depend on CI hardware.
//!
//! The PR-6 axis sweeps weight precision (f32/bf16/int8, see DESIGN.md §7
//! for the bytes/token roofline) at B ∈ {1, 8} over the same positions;
//! `bf16_speedup` (target >= 1.3x) and `int8_speedup` (target >= 1.6x) at
//! B = 1 are soft-asserted the same way.
//!
//! Emits `BENCH_native_decode.json` (path overridable) so CI can track the
//! perf trajectory across PRs. See DESIGN.md §7 for how to read it.
//!
//! Usage: cargo run --release --example perfbench -- [preset] [max_pos] [out.json]

use anyhow::Result;
use transformer_vq::json::Json;
use transformer_vq::native::{
    kernels, preset_config, DecodeSession, NativeBackend, NativeOptions, Precision, SimdMode,
};
use transformer_vq::runtime::{Backend, StateBundle};
use transformer_vq::tensor::HostTensor;

fn median_ns(window: &[f64]) -> f64 {
    let mut w: Vec<f64> = window.to_vec();
    w.sort_by(|a, b| a.partial_cmp(b).unwrap());
    w[w.len() / 2]
}

/// Drive one decode stream of `max_pos` steps; returns per-step wall ns.
/// `num_threads` = None uses the backend default (env / all cores).
fn drive(preset: &str, max_pos: usize, num_threads: Option<usize>) -> Result<Vec<f64>> {
    let backend = match num_threads {
        Some(nt) => NativeBackend::new().with_options(NativeOptions::with_threads(nt)),
        None => NativeBackend::new(),
    };
    let exe = backend.load(&format!("{preset}.decode"))?;
    let batch = exe.spec().config.batch_size;
    let mut bundle = StateBundle::zeros_for(exe.spec());
    bundle.set_named(backend.init_state(preset)?);
    let mut step_ns: Vec<f64> = Vec::with_capacity(max_pos);
    for pos in 0..max_pos {
        let tokens: Vec<i32> = (0..batch).map(|b| ((pos + b) % 251) as i32).collect();
        bundle.set_group("token", vec![HostTensor::from_i32(&[batch], &tokens)]);
        let inputs = bundle.assemble(exe.spec())?;
        let t0 = std::time::Instant::now();
        let outputs = exe.run(&inputs)?;
        step_ns.push(t0.elapsed().as_nanos() as f64);
        bundle.absorb(exe.spec(), outputs)?;
    }
    Ok(step_ns)
}

/// Median tok/s over the `window` steps preceding each position.
fn tps_at(step_ns: &[f64], positions: &[usize], window: usize, batch: usize) -> Vec<f64> {
    positions
        .iter()
        .map(|&p| 1e9 * batch as f64 / median_ns(&step_ns[p - window..p]))
        .collect()
}

/// Drive a [`DecodeSession`] (the stateful loop — no tensor round-trip)
/// for `max_pos` steps at the given batch size / SIMD mode / lane
/// strategy; returns per-step wall ns.
fn drive_session(
    preset: &str,
    batch: usize,
    max_pos: usize,
    simd: SimdMode,
    batched: bool,
    precision: Precision,
) -> Result<Vec<f64>> {
    let mut cfg = preset_config(preset)?;
    cfg.batch_size = batch;
    let name = format!("lanebench-b{batch}");
    let backend = NativeBackend::with_preset(&name, cfg, 0x1A7E).with_options(NativeOptions {
        num_threads: 0,
        simd,
        batched_decode: batched,
        precision,
    });
    let mut sess = DecodeSession::new(&backend, &name)?;
    let mut tokens = vec![0i32; batch];
    let mut step_ns: Vec<f64> = Vec::with_capacity(max_pos);
    for pos in 0..max_pos {
        for (r, t) in tokens.iter_mut().enumerate() {
            *t = ((pos + r) % 251) as i32;
        }
        let t0 = std::time::Instant::now();
        sess.step(&tokens)?;
        step_ns.push(t0.elapsed().as_nanos() as f64);
    }
    Ok(step_ns)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("quickstart");
    let max_pos: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8192);
    let out_path = args
        .get(2)
        .map(String::as_str)
        .unwrap_or("BENCH_native_decode.json");

    anyhow::ensure!(
        max_pos >= 64,
        "max_pos must be >= 64 (first reported position), got {max_pos}"
    );
    let backend = NativeBackend::new();
    let exe = backend.load(&format!("{preset}.decode"))?;
    let cfg = exe.spec().config.clone();
    let batch = cfg.batch_size;
    eprintln!(
        "perfbench: {preset}.decode  (B={batch}, S={}, L={}, positions 1..={max_pos})",
        cfg.n_code, cfg.block_len
    );

    // --- flatness baseline (default thread budget) -------------------------
    let step_ns = drive(preset, max_pos, None)?;

    // report at exponentially spaced positions: median over the preceding
    // 32 steps (median is robust to scheduler noise)
    let window = 32usize;
    let positions: Vec<usize> = [64usize, 128, 256, 512, 1024, 2048, 4096, 8192]
        .into_iter()
        .filter(|&p| p <= max_pos && p >= window)
        .collect();
    let mut ns_per_token = Vec::new();
    let mut tokens_per_sec = Vec::new();
    println!("{:>9} {:>14} {:>14}", "position", "ns/token", "tok/s");
    for &p in &positions {
        let med = median_ns(&step_ns[p - window..p]) / batch as f64;
        ns_per_token.push(med);
        let tps = 1e9 / med;
        tokens_per_sec.push(tps);
        println!("{p:>9} {med:>14.0} {tps:>14.0}");
    }

    let first = *ns_per_token.first().expect("at least one position");
    let last = *ns_per_token.last().expect("at least one position");
    let flat_ratio = last / first;
    println!(
        "flatness: pos {} is {flat_ratio:.3}x pos {} (O(S+2L) decode => ~1.0)",
        positions.last().unwrap(),
        positions.first().unwrap()
    );

    // --- thread-scaling sweep ----------------------------------------------
    let ncores = kernels::default_threads();
    let mut thread_counts = vec![1usize, 2, 4, ncores];
    thread_counts.sort_unstable();
    thread_counts.dedup();
    let sweep_positions: Vec<usize> =
        [512usize, 2048, 8192].into_iter().filter(|&p| p <= max_pos).collect();
    let mut scaling: Vec<(usize, Vec<f64>)> = Vec::new();
    if !sweep_positions.is_empty() {
        println!("\nthread scaling ({ncores} cores):");
        print!("{:>9}", "threads");
        for p in &sweep_positions {
            print!(" {:>11}", format!("tok/s@{p}"));
        }
        println!();
        // when the flatness baseline already ran at the all-cores default,
        // its timings double as the nt = ncores sweep row — don't re-drive
        let baseline_is_all_cores = NativeOptions::default().num_threads == 0;
        for &nt in &thread_counts {
            let tps = if nt == ncores && baseline_is_all_cores {
                tps_at(&step_ns, &sweep_positions, window, batch)
            } else {
                let ns = drive(preset, *sweep_positions.last().unwrap(), Some(nt))?;
                tps_at(&ns, &sweep_positions, window, batch)
            };
            print!("{nt:>9}");
            for t in &tps {
                print!(" {t:>11.0}");
            }
            println!();
            scaling.push((nt, tps));
        }
    }
    // headline speedup: 4 threads vs 1 thread at the largest seq >= 2048
    // (omitted, not approximated, when max_pos never reaches 2048)
    let speedup_4t = match (
        scaling.iter().find(|(nt, _)| *nt == 1),
        scaling.iter().find(|(nt, _)| *nt == 4),
        sweep_positions.iter().rposition(|&p| p >= 2048),
    ) {
        (Some((_, t1)), Some((_, t4)), Some(ix)) => Some(t4[ix] / t1[ix]),
        _ => None,
    };
    if let Some(s) = speedup_4t {
        println!("speedup at 4 threads (seq >= 2k): {s:.2}x");
    }

    // --- PR-5 axes on DecodeSession: SIMD on/off, batched vs per-lane ------
    let session_positions: Vec<usize> = [512usize, 2048, 8192]
        .into_iter()
        .filter(|&p| p <= max_pos && p >= window)
        .collect();
    let session_max = session_positions.last().copied().unwrap_or(0);
    // the mode the rest of this artifact actually ran under: auto-detected
    // unless the TVQ_SIMD escape hatch forced scalar (so curve labels and
    // simd_mode stay truthful under `TVQ_SIMD=0 perfbench` runs too)
    let detected = SimdMode::from_env();
    let mut simd_curves: Vec<(SimdMode, Vec<f64>)> = Vec::new();
    let mut lane_curves: Vec<(usize, bool, Vec<f64>)> = Vec::new();
    let mut precision_curves: Vec<(Precision, usize, Vec<f64>)> = Vec::new();
    let mut simd_speedup = None;
    let mut batched_speedup_b8 = None;
    let mut bf16_speedup = None;
    let mut int8_speedup = None;
    if session_max > 0 {
        let mut simd_modes = vec![detected];
        if detected != SimdMode::Scalar {
            simd_modes.push(SimdMode::Scalar);
        }
        println!("\nsimd on/off (DecodeSession, B=1, batched lanes):");
        print!("{:>9}", "simd");
        for p in &session_positions {
            print!(" {:>11}", format!("tok/s@{p}"));
        }
        println!();
        for &simd in &simd_modes {
            let ns = drive_session(preset, 1, session_max, simd, true, Precision::F32)?;
            let tps = tps_at(&ns, &session_positions, window, 1);
            print!("{:>9}", simd.name());
            for t in &tps {
                print!(" {t:>11.0}");
            }
            println!();
            simd_curves.push((simd, tps));
        }
        if simd_curves.len() == 2 {
            let on = simd_curves[0].1.last().copied().unwrap_or(0.0);
            let off = simd_curves[1].1.last().copied().unwrap_or(f64::INFINITY);
            simd_speedup = Some(on / off);
        }

        println!("\nbatched vs per-lane (DecodeSession, simd={}):", detected.name());
        print!("{:>9} {:>9}", "batch", "lanes");
        for p in &session_positions {
            print!(" {:>11}", format!("tok/s@{p}"));
        }
        println!();
        for &bsz in &[1usize, 4, 8] {
            for &batched in &[true, false] {
                let ns = drive_session(preset, bsz, session_max, detected, batched, Precision::F32)?;
                let tps = tps_at(&ns, &session_positions, window, bsz);
                print!("{bsz:>9} {:>9}", if batched { "batched" } else { "per-lane" });
                for t in &tps {
                    print!(" {t:>11.0}");
                }
                println!();
                lane_curves.push((bsz, batched, tps));
            }
        }
        let last_of = |bsz: usize, batched: bool| {
            lane_curves
                .iter()
                .find(|(b, m, _)| *b == bsz && *m == batched)
                .and_then(|(_, _, tps)| tps.last().copied())
        };
        if let (Some(on), Some(off)) = (last_of(8, true), last_of(8, false)) {
            batched_speedup_b8 = Some(on / off);
        }

        // soft assertions: always recorded, warn (don't fail) on a miss —
        // both ratios depend on CI hardware (ISSUE 5 acceptance targets)
        if let Some(s) = simd_speedup {
            let verdict = if s >= 1.5 { "OK" } else { "BELOW TARGET (soft)" };
            println!(
                "simd speedup at B=1, pos {session_max}: {s:.2}x (target >= 1.5x) {verdict}"
            );
        }
        if let Some(s) = batched_speedup_b8 {
            let verdict = if s >= 2.0 { "OK" } else { "BELOW TARGET (soft)" };
            println!(
                "batched-lane speedup at B=8, pos {session_max}: {s:.2}x \
                 (target >= 2x) {verdict}"
            );
        }

        // --- PR-6 axis: weight precision f32/bf16/int8 ---------------------
        println!(
            "\nprecision sweep (DecodeSession, simd={}, batched lanes):",
            detected.name()
        );
        print!("{:>9} {:>9}", "precision", "batch");
        for p in &session_positions {
            print!(" {:>11}", format!("tok/s@{p}"));
        }
        println!();
        for &bsz in &[1usize, 8] {
            for &precision in &[Precision::F32, Precision::Bf16, Precision::Int8] {
                let ns = drive_session(preset, bsz, session_max, detected, true, precision)?;
                let tps = tps_at(&ns, &session_positions, window, bsz);
                print!("{:>9} {bsz:>9}", precision.name());
                for t in &tps {
                    print!(" {t:>11.0}");
                }
                println!();
                precision_curves.push((precision, bsz, tps));
            }
        }
        // headline ratios: reduced-precision vs f32, B=1, largest position
        let prec_last = |precision: Precision, bsz: usize| {
            precision_curves
                .iter()
                .find(|(p, b, _)| *p == precision && *b == bsz)
                .and_then(|(_, _, tps)| tps.last().copied())
        };
        if let (Some(base), Some(b16), Some(i8t)) = (
            prec_last(Precision::F32, 1),
            prec_last(Precision::Bf16, 1),
            prec_last(Precision::Int8, 1),
        ) {
            bf16_speedup = Some(b16 / base);
            int8_speedup = Some(i8t / base);
        }
        if let Some(s) = bf16_speedup {
            let verdict = if s >= 1.3 { "OK" } else { "BELOW TARGET (soft)" };
            println!(
                "bf16 speedup at B=1, pos {session_max}: {s:.2}x (target >= 1.3x) {verdict}"
            );
        }
        if let Some(s) = int8_speedup {
            let verdict = if s >= 1.6 { "OK" } else { "BELOW TARGET (soft)" };
            println!(
                "int8 speedup at B=1, pos {session_max}: {s:.2}x (target >= 1.6x) {verdict}"
            );
        }
    }

    let jarr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::num(x)).collect());
    let jpos = |v: &[usize]| Json::Arr(v.iter().map(|&p| Json::num(p as f64)).collect());
    let mut fields = vec![
        ("bench", Json::str("native_decode")),
        ("preset", Json::str(preset)),
        ("batch", Json::num(batch as f64)),
        ("n_code", Json::num(cfg.n_code as f64)),
        ("block_len", Json::num(cfg.block_len as f64)),
        ("positions", jpos(&positions)),
        ("ns_per_token", jarr(&ns_per_token)),
        ("tokens_per_sec", jarr(&tokens_per_sec)),
        ("flat_ratio_last_vs_first", Json::num(flat_ratio)),
        ("cores", Json::num(ncores as f64)),
        ("scaling_positions", jpos(&sweep_positions)),
        (
            "thread_scaling",
            Json::Arr(
                scaling
                    .iter()
                    .map(|(nt, tps)| {
                        Json::obj(vec![
                            ("threads", Json::num(*nt as f64)),
                            ("tokens_per_sec", jarr(tps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(s) = speedup_4t {
        fields.push(("speedup_threads4_vs_1", Json::num(s)));
    }
    fields.push(("simd_mode", Json::str(detected.name())));
    fields.push(("batched_decode_default", Json::num(1.0)));
    fields.push(("session_positions", jpos(&session_positions)));
    fields.push((
        "simd_curves",
        Json::Arr(
            simd_curves
                .iter()
                .map(|(mode, tps)| {
                    Json::obj(vec![
                        ("simd", Json::str(mode.name())),
                        ("batch", Json::num(1.0)),
                        ("tokens_per_sec", jarr(tps)),
                    ])
                })
                .collect(),
        ),
    ));
    fields.push((
        "lane_curves",
        Json::Arr(
            lane_curves
                .iter()
                .map(|(bsz, batched, tps)| {
                    Json::obj(vec![
                        ("batch", Json::num(*bsz as f64)),
                        ("mode", Json::str(if *batched { "batched" } else { "per_lane" })),
                        ("tokens_per_sec", jarr(tps)),
                    ])
                })
                .collect(),
        ),
    ));
    fields.push((
        "precision_curves",
        Json::Arr(
            precision_curves
                .iter()
                .map(|(precision, bsz, tps)| {
                    Json::obj(vec![
                        ("precision", Json::str(precision.name())),
                        ("batch", Json::num(*bsz as f64)),
                        ("tokens_per_sec", jarr(tps)),
                    ])
                })
                .collect(),
        ),
    ));
    if let Some(s) = simd_speedup {
        fields.push(("simd_speedup", Json::num(s)));
    }
    if let Some(s) = batched_speedup_b8 {
        fields.push(("batched_speedup_b8", Json::num(s)));
    }
    if let Some(s) = bf16_speedup {
        fields.push(("bf16_speedup", Json::num(s)));
    }
    if let Some(s) = int8_speedup {
        fields.push(("int8_speedup", Json::num(s)));
    }
    let j = Json::obj(fields);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");

    assert!(
        flat_ratio < 1.5,
        "decode latency is not flat: position {} is {flat_ratio:.2}x position {}",
        positions.last().unwrap(),
        positions.first().unwrap()
    );
    println!("perfbench OK: per-token decode latency is flat in sequence position");
    Ok(())
}
