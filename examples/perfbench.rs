//! Native decode perf baseline: per-token latency vs sequence position.
//!
//! The paper's serving claim (Remark 3.8) is that VQ decode costs
//! O(S + 2L) per token — *independent of position*. This bench drives the
//! native backend's `<preset>.decode` executor for thousands of consecutive
//! positions without resetting, records per-step wall time, and reports
//! tokens/sec at exponentially spaced positions. A quadratic-cache model
//! would slow down linearly with position; this one must stay flat
//! (position 4096 within 1.5x of position 64 — asserted).
//!
//! Emits `BENCH_native_decode.json` (path overridable) so CI can track the
//! perf trajectory across PRs.
//!
//! Usage: cargo run --release --example perfbench -- [preset] [max_pos] [out.json]

use anyhow::Result;
use transformer_vq::json::Json;
use transformer_vq::native::NativeBackend;
use transformer_vq::runtime::{Backend, StateBundle};
use transformer_vq::tensor::HostTensor;

fn median_ns(window: &[f64]) -> f64 {
    let mut w: Vec<f64> = window.to_vec();
    w.sort_by(|a, b| a.partial_cmp(b).unwrap());
    w[w.len() / 2]
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("quickstart");
    let max_pos: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4096);
    let out_path = args
        .get(2)
        .map(String::as_str)
        .unwrap_or("BENCH_native_decode.json");

    anyhow::ensure!(
        max_pos >= 64,
        "max_pos must be >= 64 (first reported position), got {max_pos}"
    );
    let backend = NativeBackend::new();
    let exe = backend.load(&format!("{preset}.decode"))?;
    let cfg = exe.spec().config.clone();
    let batch = cfg.batch_size;
    eprintln!(
        "perfbench: {preset}.decode  (B={batch}, S={}, L={}, positions 1..={max_pos})",
        cfg.n_code, cfg.block_len
    );

    let mut bundle = StateBundle::zeros_for(exe.spec());
    bundle.set_named(backend.init_state(preset)?);

    // drive one long sequence per slot, timing every step
    let mut step_ns: Vec<f64> = Vec::with_capacity(max_pos);
    for pos in 0..max_pos {
        let tokens: Vec<i32> = (0..batch).map(|b| ((pos + b) % 251) as i32).collect();
        bundle.set_group("token", vec![HostTensor::from_i32(&[batch], &tokens)]);
        let inputs = bundle.assemble(exe.spec())?;
        let t0 = std::time::Instant::now();
        let outputs = exe.run(&inputs)?;
        step_ns.push(t0.elapsed().as_nanos() as f64);
        bundle.absorb(exe.spec(), outputs)?;
    }

    // report at exponentially spaced positions: median over the preceding
    // 32 steps (median is robust to scheduler noise)
    let window = 32usize;
    let positions: Vec<usize> = [64usize, 128, 256, 512, 1024, 2048, 4096, 8192]
        .into_iter()
        .filter(|&p| p <= max_pos && p >= window)
        .collect();
    let mut ns_per_token = Vec::new();
    let mut tokens_per_sec = Vec::new();
    println!("{:>9} {:>14} {:>14}", "position", "ns/token", "tok/s");
    for &p in &positions {
        let med = median_ns(&step_ns[p - window..p]) / batch as f64;
        ns_per_token.push(med);
        let tps = 1e9 / med;
        tokens_per_sec.push(tps);
        println!("{p:>9} {med:>14.0} {tps:>14.0}");
    }

    let first = *ns_per_token.first().expect("at least one position");
    let last = *ns_per_token.last().expect("at least one position");
    let flat_ratio = last / first;
    println!(
        "flatness: pos {} is {flat_ratio:.3}x pos {} (O(S+2L) decode => ~1.0)",
        positions.last().unwrap(),
        positions.first().unwrap()
    );

    let jarr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::num(x)).collect());
    let j = Json::obj(vec![
        ("bench", Json::str("native_decode")),
        ("preset", Json::str(preset)),
        ("batch", Json::num(batch as f64)),
        ("n_code", Json::num(cfg.n_code as f64)),
        ("block_len", Json::num(cfg.block_len as f64)),
        (
            "positions",
            Json::Arr(positions.iter().map(|&p| Json::num(p as f64)).collect()),
        ),
        ("ns_per_token", jarr(&ns_per_token)),
        ("tokens_per_sec", jarr(&tokens_per_sec)),
        ("flat_ratio_last_vs_first", Json::num(flat_ratio)),
    ]);
    std::fs::write(out_path, j.dump())?;
    println!("wrote {out_path}");

    assert!(
        flat_ratio < 1.5,
        "decode latency is not flat: position {} is {flat_ratio:.2}x position {}",
        positions.last().unwrap(),
        positions.first().unwrap()
    );
    println!("perfbench OK: per-token decode latency is flat in sequence position");
    Ok(())
}
