//! Perf-iteration tool (§Perf in EXPERIMENTS.md): benchmark every *.train
//! artifact in a directory of perf-variant artifacts and print per-step
//! latency + throughput. Variants are lowered by python (see EXPERIMENTS.md
//! §Perf for the recipe); this binary is the timing half of the
//! measure -> change one thing -> re-measure loop.
//!
//! Usage: perfbench [artifacts_dir]   (default /tmp/perfvariants)

use transformer_vq::bench::Bencher;
use transformer_vq::manifest::Manifest;
use transformer_vq::runtime::{Runtime, StateBundle};

fn main() {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/perfvariants".to_string());
    let manifest = Manifest::load(&dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let bencher = Bencher {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: 40,
        budget: std::time::Duration::from_secs(4),
    };
    for name in manifest.artifacts.keys() {
        let exe = runtime.load(&manifest, name).unwrap();
        let preset = name.trim_end_matches(".train");
        let mut bundle = StateBundle::zeros_for(&exe.spec);
        let init = manifest.init_path(preset);
        if init.exists() {
            bundle.load_groups(init).unwrap();
        }
        let inputs = bundle.assemble(&exe.spec).unwrap();
        let lits = exe.to_literals(&inputs).unwrap();
        let stats = bencher.run(name, || {
            exe.run_literals(&lits).unwrap();
        });
        let toks = (exe.spec.config.window_len * exe.spec.config.batch_size) as f64;
        println!(
            "{:<24} {:>10.3?}/step  {:>8.0} tok/s",
            name,
            stats.mean,
            toks / stats.mean_secs()
        );
    }
}
