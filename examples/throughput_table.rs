//! Regenerate the paper's throughput tables (Tables 6-9 analogues):
//! Full vs VQ-Attention training throughput across head types (SHGA/MQA/
//! MHA), sequence lengths, and cross-block reduction methods.
//!
//! Sequence lengths are scaled 8-32x down from the paper's TPU v3 runs
//! (CPU PJRT backend); the quadratic-vs-linear *scaling shape* — the claim
//! under test — is hardware independent.
//!
//! Usage: cargo run --release --example throughput_table -- [max_T] [budget_s]

use anyhow::Result;
use transformer_vq::bench::Bencher;
use transformer_vq::paperbench::{measure_throughput_grid, print_throughput_tables};
use transformer_vq::runtime::auto_backend;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_t: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(4096);
    let budget: u64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3);

    let backend = auto_backend(transformer_vq::artifacts_dir())?;
    let bencher = Bencher {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 30,
        budget: std::time::Duration::from_secs(budget),
    };
    eprintln!(
        "measuring throughput grid (T <= {max_t}, {} backend) ...",
        backend.platform()
    );
    let rows = measure_throughput_grid(backend.as_ref(), &bencher, max_t)?;
    print_throughput_tables(&rows);

    // headline check (abstract): VQ speedup at the longest T, SHGA
    let mut lens: Vec<usize> = rows.iter().map(|r| r.seq_len).collect();
    lens.sort_unstable();
    let t_max = *lens.last().unwrap();
    let f = rows.iter().find(|r| r.head == "shga" && r.variant == "full" && r.seq_len == t_max);
    let v = rows
        .iter()
        .find(|r| r.head == "shga" && r.variant == "vq-matmul" && r.seq_len == t_max);
    if let (Some(f), Some(v)) = (f, v) {
        println!(
            "\nheadline: at T={t_max}, VQ is {:.2}x the throughput of Full (SHGA)",
            v.tokens_per_sec / f.tokens_per_sec
        );
    }
    Ok(())
}
