//! Debug tool: load an arbitrary HLO text file, feed zero inputs (or
//! inputs from a TVQ file), print outputs / write them to a TVQ file.
//! Usage: runhlo <file.hlo.txt> [in.tvq] [out.tvq]
use anyhow::Result;
use transformer_vq::runtime::tensor_to_literal;
use transformer_vq::store::{read_tvq, write_tvq};
use transformer_vq::tensor::{DType, HostTensor};

fn main() -> Result<()> {
    let path = std::env::args().nth(1).expect("usage: runhlo <hlo.txt> [in.tvq] [out.tvq]");
    let in_tvq = std::env::args().nth(2);
    let out_tvq = std::env::args().nth(3);
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    // parse parameter shapes from the entry_computation_layout header line
    let text = std::fs::read_to_string(&path)?;
    let header = text.lines().next().unwrap();
    let inner = header.split("entry_computation_layout={(").nth(1)
        .and_then(|s| s.split(")->").next())
        .expect("no entry_computation_layout");
    let mut args = Vec::new();
    match &in_tvq {
        Some(p) => {
            for (_, t) in read_tvq(p)? {
                args.push(tensor_to_literal(&t)?);
            }
        }
        None => {
            for spec in split_top(inner) {
                args.push(zero_literal(spec.trim())?);
            }
        }
    }
    let result = exe.execute::<xla::Literal>(&args).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let mut saved: Vec<(String, HostTensor)> = Vec::new();
    for (i, buf) in result[0].iter().enumerate() {
        let mut lit = buf.to_literal_sync().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let parts = match lit.decompose_tuple() { Ok(p) => p, Err(_) => vec![lit] };
        for (j, p) in parts.iter().enumerate() {
            print_literal(i, j, p);
            if out_tvq.is_some() {
                saved.push((format!("out{i}_{j}"), literal_to_host(p)?));
            }
        }
    }
    if let Some(p) = out_tvq {
        write_tvq(p, &saved)?;
    }
    Ok(())
}

fn literal_to_host(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let n: usize = dims.iter().product();
    match lit.ty().map_err(|e| anyhow::anyhow!("{e:?}"))? {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(HostTensor::from_f32(&dims, &v))
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            Ok(HostTensor::from_i32(&dims, &v))
        }
        other => anyhow::bail!("unsupported output type {other:?} ({n} elems)"),
    }
}

fn split_top(s: &str) -> Vec<String> {
    // split on commas not inside brackets/braces
    let mut out = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '[' | '{' => { depth += 1; cur.push(c); }
            ']' | '}' => { depth -= 1; cur.push(c); }
            ',' if depth == 0 => { out.push(cur.clone()); cur.clear(); }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() { out.push(cur); }
    out
}

fn zero_literal(spec: &str) -> Result<xla::Literal> {
    // spec like "f32[8,4]{1,0}" or "s32[3]{0}" or "f32[]",
    // possibly prefixed with "/*index=N*/" comments
    let spec = match spec.rfind("*/") {
        Some(i) => spec[i + 2..].trim(),
        None => spec,
    };
    let ty = if spec.starts_with("f32") { xla::ElementType::F32 }
        else if spec.starts_with("s32") { xla::ElementType::S32 }
        else if spec.starts_with("u32") { xla::ElementType::U32 }
        else { anyhow::bail!("unknown type in {spec}") };
    let dims_str = spec.split('[').nth(1).and_then(|s| s.split(']').next()).unwrap_or("");
    let dims: Vec<usize> = if dims_str.is_empty() { vec![] }
        else { dims_str.split(',').map(|d| d.trim().parse().unwrap()).collect() };
    let n: usize = dims.iter().product();
    xla::Literal::create_from_shape_and_untyped_data(ty, &dims, &vec![0u8; n * 4])
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

fn print_literal(i: usize, j: usize, lit: &xla::Literal) {
    let ty = lit.ty();
    match ty {
        Ok(xla::ElementType::F32) => {
            let v = lit.to_vec::<f32>().unwrap();
            println!("out[{i}][{j}] f32 {:?}", &v[..v.len().min(6)]);
        }
        Ok(xla::ElementType::S32) => {
            let v = lit.to_vec::<i32>().unwrap();
            println!("out[{i}][{j}] s32 {:?}", &v[..v.len().min(6)]);
        }
        other => println!("out[{i}][{j}] ty {other:?}"),
    }
}
