"""L2: Transformer-VQ language model — fwd/bwd compute graph.

Pure-functional LM over byte/BPE tokens. One call processes a training window
of W tokens (R = W/L blocks) and threads the recurrent carry (compressive
cache + previous block per layer), per §3.4.2 of the paper.

Never imported at runtime: ``aot.py`` lowers the step functions in steps.py
(which call into this module) to HLO text once, and the rust coordinator
drives the artifacts.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import VQConfig
from . import layers
from .kernels import vq

MAX_ABS_POS = 1 << 30  # position wrap bound (abs PE computed at runtime)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: VQConfig) -> Dict:
    keys = jax.random.split(key, 2 * cfg.n_layers + 3)
    p: Dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
        * 0.02,
        "ln_f": jnp.ones((cfg.d_model,)),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        lp = {"attn": layers.init_attn_layer(keys[2 * i + 1], cfg)}
        if cfg.head_type in ("mha", "mqa"):
            lp["mlp"] = layers.init_mlp_layer(keys[2 * i + 2], cfg)
        p["layers"].append(lp)
    if not cfg.tie_embeddings:
        p["head"] = layers.dense_init(keys[-2], cfg.d_model, cfg.vocab_size)
    if cfg.use_abs_pe:
        p["pe_scale"] = jnp.ones(())
    return p


def init_cb_states(key, cfg: VQConfig) -> List[Dict]:
    """Per-layer EMA codebook states (empty list for the full baseline)."""
    if cfg.attn_type != "vq":
        return []
    keys = jax.random.split(key, cfg.n_layers)
    scale = 1.0 / math.sqrt(cfg.tau_value)  # match rms-normed tau-scaled keys
    return [
        vq.codebook_init(keys[i], cfg.n_kv_heads, cfg.n_code, cfg.d_k,
                         scale=scale)
        for i in range(cfg.n_layers)
    ]


def init_carry(cfg: VQConfig, batch: int) -> Dict:
    return {
        "layers": [layers.init_layer_carry(cfg, batch)
                   for _ in range(cfg.n_layers)],
        "has_prev": jnp.zeros((batch,)),
        "pos": jnp.zeros((batch,), dtype=jnp.int32),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed(params, cfg: VQConfig, tokens, pos0):
    x = params["embed"][tokens]                        # [B, W, Dm]
    if cfg.use_abs_pe:
        w = tokens.shape[1]
        pos = pos0[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
        x = x + params["pe_scale"] * layers.sinusoid_at(pos, cfg.d_model)
    return x


def _logits(params, cfg: VQConfig, x):
    h = layers.rmsnorm(x, params["ln_f"])
    if cfg.tie_embeddings:
        return h @ (params["embed"].T / math.sqrt(cfg.d_model))
    return h @ params["head"]


def forward_window(
    params: Dict, cb_states: List[Dict], carry: Dict, tokens: jnp.ndarray,
    cfg: VQConfig, rng, train: bool,
) -> Tuple[jnp.ndarray, Dict, Dict]:
    """tokens [B, W] -> (logits [B, W, V], new_carry, aux).

    aux = {"commit": scalar, "ema": [(k_raw, z) per vq layer]}.
    """
    if cfg.reduction == "inputscan" and cfg.blocks_per_window > 1:
        return _forward_inputscan(params, cb_states, carry, tokens, cfg, rng,
                                  train)
    x = _embed(params, cfg, tokens, carry["pos"])
    has_prev = carry["has_prev"]
    new_layer_carries = []
    commit_total = jnp.zeros(())
    ema_pairs = []
    rngs = jax.random.split(rng, 2 * cfg.n_layers + 1)
    for i, lp in enumerate(params["layers"]):
        cb = cb_states[i] if cfg.attn_type == "vq" else None
        x, lcarry, aux = layers.attn_sublayer(
            lp["attn"], cb, carry["layers"][i], has_prev, x, cfg,
            rngs[2 * i], train)
        new_layer_carries.append(lcarry)
        commit_total = commit_total + aux["commit"]
        if aux["k_raw"] is not None:
            ema_pairs.append((aux["k_raw"], aux["z"]))
        if "mlp" in lp:
            x = layers.mlp_sublayer(lp["mlp"], x, cfg, rngs[2 * i + 1], train)
    logits = _logits(params, cfg, x)
    new_carry = {
        "layers": new_layer_carries,
        "has_prev": jnp.ones_like(has_prev),
        "pos": carry["pos"] + tokens.shape[1],
    }
    return logits, new_carry, {"commit": commit_total, "ema": ema_pairs}


def _forward_inputscan(params, cb_states, carry, tokens, cfg, rng, train):
    """Table 9 variant: lax.scan over L-blocks, all layers inside the body.

    Mathematically identical to the batched-window forward (asserted in
    python/tests/test_model.py); trades parallelism for O(L) activation
    memory, mirroring Wu et al. / Hutchins et al. input scanning.
    """
    b, w = tokens.shape
    l = cfg.block_len
    r = w // l
    blocks = tokens.reshape(b, r, l)
    cfg_blk = cfg.replace(reduction="serial", window_len=l)

    def body(state, blk):
        carry_s, rng_s = state
        rng_s, sub = jax.random.split(rng_s)
        logits, new_carry, aux = forward_window(
            params, cb_states, carry_s, blk, cfg_blk, sub, train)
        ema_flat = tuple(x for pair in aux["ema"] for x in pair)
        return (new_carry, rng_s), (logits, aux["commit"], ema_flat)

    (final_carry, _), (logits, commits, ema_flat) = jax.lax.scan(
        body, (carry, rng), jnp.moveaxis(blocks, 1, 0))
    logits = jnp.moveaxis(logits, 0, 1).reshape(b, w, -1)
    # re-pair ema tensors: scan stacked the block axis at dim 0
    ema_pairs = []
    for i in range(0, len(ema_flat), 2):
        kk = jnp.moveaxis(ema_flat[i], 0, 1).reshape(
            b, w, *ema_flat[i].shape[3:])
        zz = jnp.moveaxis(ema_flat[i + 1], 0, 1).reshape(
            b, w, *ema_flat[i + 1].shape[3:])
        ema_pairs.append((kk, zz))
    return logits, final_carry, {"commit": jnp.sum(commits) / r,
                                 "ema": ema_pairs}


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(params, cb_states, carry, inputs, targets, cfg: VQConfig, rng,
            train: bool):
    """Average next-token CE + beta * summed commit losses (eq. 35-37)."""
    logits, new_carry, aux = forward_window(
        params, cb_states, carry, inputs, cfg, rng, train)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: the deployed PJRT
    # runtime miscompiles some gather forms (probe.py / DESIGN.md)
    onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=logp.dtype)
    ce_tok = -jnp.sum(onehot * logp, axis=-1)
    ce = jnp.mean(ce_tok)
    loss = ce + cfg.commit_coef * aux["commit"]
    return loss, (ce, aux["commit"], new_carry, aux["ema"])


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
