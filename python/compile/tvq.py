"""TVQ tensor-store: the binary interchange format between python and rust.

Layout:  b"TVQ1" | u32 header_len (LE) | JSON header | raw tensor data.
Header: {"tensors": [{"name", "dtype" ("f32"|"i32"|"u32"), "shape",
"offset", "nbytes"}]} — offsets relative to the start of the data section.
All data little-endian, C-contiguous. The rust reader/writer lives in
rust/src/store.rs and round-trips bit-exactly (asserted in cargo tests
against files generated here).
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Sequence, Tuple

import numpy as np

MAGIC = b"TVQ1"

_DTYPES = {
    np.dtype(np.float32): "f32",
    np.dtype(np.int32): "i32",
    np.dtype(np.uint32): "u32",
}
_NP_DTYPES = {v: k for k, v in _DTYPES.items()}


def write(path: str, tensors: Sequence[Tuple[str, np.ndarray]]) -> None:
    metas: List[Dict] = []
    blobs: List[bytes] = []
    off = 0
    for name, arr in tensors:
        shape = list(np.shape(arr))
        # NB: ascontiguousarray promotes 0-d arrays to 1-d; restore shape.
        arr = np.ascontiguousarray(arr).reshape(shape)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        if arr.dtype == np.int64:
            arr = arr.astype(np.int32)
        dt = _DTYPES[arr.dtype]
        raw = arr.tobytes()
        metas.append({"name": name, "dtype": dt, "shape": list(arr.shape),
                      "offset": off, "nbytes": len(raw)})
        blobs.append(raw)
        off += len(raw)
    header = json.dumps({"tensors": metas}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def read(path: str) -> List[Tuple[str, np.ndarray]]:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"bad magic {magic!r} in {path}"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen).decode("utf-8"))
        data = f.read()
    out = []
    for m in header["tensors"]:
        raw = data[m["offset"]:m["offset"] + m["nbytes"]]
        arr = np.frombuffer(raw, dtype=_NP_DTYPES[m["dtype"]]).reshape(
            m["shape"]).copy()
        out.append((m["name"], arr))
    return out
