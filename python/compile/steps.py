"""Step functions lowered to HLO artifacts.

Each function is pure: (state..., inputs...) -> (state'..., outputs...). The
rust coordinator owns all state between calls — params, AdamW moments,
codebook EMAs, recurrent carry — so checkpointing/resume is trivial and the
artifacts contain no host callbacks.

The learning rate arrives as a scalar input: the LR schedule (linear warmup +
cosine decay, Appendix C) lives in the rust scheduler (L3), keeping policy
out of the compiled graph.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import VQConfig
from . import model
from .kernels import vq


# ---------------------------------------------------------------------------
# AdamW (in-graph; Appendix C hyperparameters)
# ---------------------------------------------------------------------------

def init_opt_state(params) -> Dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros(())}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, opt, lr, cfg: VQConfig):
    """Returns (new_params, new_opt, grad_norm). Decay skips 1-D tensors
    (norm gains, scales) following Radford et al. 2019."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * clip, grads)
    step = opt["step"] + 1.0
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    bc1 = 1.0 - jnp.power(b1, step)
    bc2 = 1.0 - jnp.power(b2, step)

    def upd(p, g, m, v):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2 and cfg.weight_decay > 0.0:
            delta = delta + cfg.weight_decay * p
        return p - lr * delta, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def train_step(params, opt, cb_states: List[Dict], carry, tokens, lr, seed,
               cfg: VQConfig):
    """One §3.4.2 update over a window of W tokens.

    tokens [B, W+1] (inputs ‖ next-token targets). Returns
    (params', opt', cb_states', carry', metrics [6]):
    metrics = [loss, ce, commit, grad_norm, code_perplexity, lr].
    """
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    rng = jax.random.PRNGKey(seed)

    (loss, (ce, commit, new_carry, ema_pairs)), grads = jax.value_and_grad(
        model.loss_fn, has_aux=True)(
        params, cb_states, carry, inputs, targets, cfg, rng, True)

    new_params, new_opt, gnorm = adamw_update(params, grads, opt, lr, cfg)

    new_cbs = []
    perplexities = []
    for cb, (k_raw, z) in zip(cb_states, ema_pairs):
        new_cbs.append(vq.ema_update(cb, k_raw, z, cfg.ema_rate))
        perplexities.append(vq.codebook_perplexity(z, cfg.n_code))
    perp = (jnp.mean(jnp.stack(perplexities)) if perplexities
            else jnp.zeros(()))

    metrics = jnp.stack([loss, ce, commit, gnorm, perp, lr])
    return new_params, new_opt, new_cbs, new_carry, metrics


def eval_step(params, cb_states, carry, tokens, cfg: VQConfig):
    """Windowed evaluation. tokens [B, W+1] -> (carry', metrics [2] =
    [sum CE over window, token count])."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    rng = jax.random.PRNGKey(0)
    _, (ce, _, new_carry, _) = model.loss_fn(
        params, cb_states, carry, inputs, targets, cfg, rng, False)
    n_tok = jnp.asarray(inputs.size, dtype=jnp.float32)
    metrics = jnp.stack([ce * n_tok, n_tok])
    return new_carry, metrics


def fwdbwd_bench(params, cb_states, carry, tokens, cfg: VQConfig):
    """Throughput benchmark body (Tables 6-9): forward + backward over a full
    sequence of length T = window_len; returns the loss and the gradient
    global norm so XLA cannot DCE the backward pass."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    rng = jax.random.PRNGKey(0)
    (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, cb_states, carry, inputs, targets, cfg, rng, True)
    return jnp.stack([loss, global_norm(grads)])
