"""Transformer-VQ layers: GAU / MHA / MQA attention with VQ or full attention.

All functions are pure: parameters and recurrent state are explicit pytrees,
so every entry point lowers to a single self-contained HLO module that the
rust coordinator drives (state in, state out). Windowed training follows
§3.4.2 of the paper: each call processes W = R*L tokens and carries the
compressive cache + previous block across windows (truncated backprop —
carried tensors are stop-gradient'ed).

Carry layout per attention layer (Bh = batch, Hk = kv heads):
  cache_u [B, Hk, S, Dvh]  running mean of values per shortcode, blocks < g-1
  cache_l [B, Hk, S]       running counts
  prev_k  [B, Hk, L, Dk]   quantized keys of block g-1
  prev_v  [B, Hk, L, Dvh]  values of block g-1
  prev_z  [B, Hk, L] i32   shortcodes of block g-1 (to fold it into the cache
                           once it leaves the positional-bias band)
plus a model-level {"has_prev": [B] f32, "pos": [B] i32} entry.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import VQConfig
from .kernels import vq
from .kernels import reductions as red
from .kernels.vq_attn import combine_jnp, combine_pallas, NEG_INF


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, gain=None, eps: float = 1e-6) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    if gain is not None:
        y = y * gain
    return y


def dense_init(key, fan_in: int, fan_out: int) -> jnp.ndarray:
    """PaLM-style variance-scaling init (Chowdhery et al. 2022)."""
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, (fan_in, fan_out)) * std


def sinusoid_table(n_pos: int, dim: int, max_wavelength: float = 1e5):
    """Fixed sinusoidal features; rows indexed by (relative) position.

    Only used for small tables (2L rows); absolute PE uses sinusoid_at to
    avoid baking a 16k-row constant into the HLO text.
    """
    pos = np.arange(n_pos)[:, None].astype(np.float64)
    i = np.arange(dim // 2)[None, :].astype(np.float64)
    angle = pos / np.power(max_wavelength, 2 * i / dim)
    tab = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(tab, dtype=jnp.float32)


def sinusoid_at(pos: jnp.ndarray, dim: int, max_wavelength: float = 1e5):
    """Sinusoidal features computed at runtime for integer positions `pos`
    (any shape). Returns [..., dim]. Constant-free (runtime sin/cos), so
    arbitrarily long sequences cost nothing in artifact size."""
    i = jnp.arange(dim // 2, dtype=jnp.float32)
    inv_freq = jnp.power(max_wavelength, -2.0 * i / dim)
    angle = pos[..., None].astype(jnp.float32) * inv_freq
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def dropout(x, rate: float, key, train: bool):
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


# ---------------------------------------------------------------------------
# relative positional biases (Transformer-XL style, local band only)
# ---------------------------------------------------------------------------

def rel_bias_all(q: jnp.ndarray, w_r: jnp.ndarray, block_len: int,
                 tau_rsqrt: float) -> jnp.ndarray:
    """Per-distance biases: out[..., i, d] = q_i . (phi(d) @ w_r) / sqrt(tau).

    q [Bf, R, L, Dk] (already tau-scaled), w_r [Dk, Dk]; distances
    d in [0, 2L-1]. Returns [Bf, R, L, 2L].
    """
    phi = sinusoid_table(2 * block_len, w_r.shape[0])      # [2L, Dk]
    rp = (phi @ w_r) * tau_rsqrt                           # [2L, Dk]
    return jnp.einsum("brid,ed->brie", q, rp)


def gather_band_biases(bias_all: jnp.ndarray, block_len: int):
    """Split per-distance biases into (bias_cur, bias_prev) [.., L, L].

    bias_cur[i, j] = bias_all[i, i-j] + causal mask; bias_prev[i, j] =
    bias_all[i, L+i-j] (query i of block n against key j of block n-1).

    Implemented with *static* per-row slices + flips instead of a gather:
    the indices are compile-time constants, and the deployed PJRT runtime
    (xla_extension 0.5.1) miscompiles jax 0.8's constant-index gather form
    (returns fill-NaNs / wrong rows; see python/compile/probe.py and
    DESIGN.md §Runtime-compat).
    """
    l = block_len
    i = np.arange(l)[:, None]
    j = np.arange(l)[None, :]
    causal = jnp.asarray((i - j < 0) * NEG_INF, dtype=bias_all.dtype)
    # pad distances so row i's "current block" window is a plain slice:
    # padded[..., i, l-1 + d] = bias_all[..., i, d]
    pad = [(0, 0)] * (bias_all.ndim - 1) + [(l - 1, 0)]
    padded = jnp.pad(bias_all, pad)
    rows_cur = [jnp.flip(padded[..., r, r:r + l], axis=-1) for r in range(l)]
    bias_cur = jnp.stack(rows_cur, axis=-2) + causal
    # prev block: distances d = l+i-j for j in [0,l) => slice [i+1, i+l]
    rows_prev = [jnp.flip(bias_all[..., r, r + 1:r + 1 + l], axis=-1)
                 for r in range(l)]
    bias_prev = jnp.stack(rows_prev, axis=-2)
    return bias_cur, bias_prev


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------

def init_attn_layer(key, cfg: VQConfig) -> Dict:
    dm, dk, dv = cfg.d_model, cfg.d_k, cfg.d_v
    h, hk = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "ln_x": jnp.ones((dm,)),
        "wq": dense_init(ks[0], dm, h * dk),
        "wk": dense_init(ks[1], dm, hk * dk),
        "wv": dense_init(ks[2], dm, hk * cfg.d_v_head),
        "wr": dense_init(ks[3], dk, h * dk).reshape(dk, h, dk),
        "wo": dense_init(ks[4], dv, dm),
    }
    if cfg.head_type == "shga":
        p["wg"] = dense_init(ks[5], dm, dv)
    return p


def init_mlp_layer(key, cfg: VQConfig) -> Dict:
    dm, dff = cfg.d_model, cfg.d_v  # Dff = Dv keeps params comparable to GAU
    ks = jax.random.split(key, 2)
    return {
        "ln": jnp.ones((dm,)),
        "w1": dense_init(ks[0], dm, 2 * dff),
        "w2": dense_init(ks[1], dff, dm),
    }


def init_layer_carry(cfg: VQConfig, batch: int) -> Dict:
    hk, s, l = cfg.n_kv_heads, cfg.n_code, cfg.block_len
    dk, dvh = cfg.d_k, cfg.d_v_head
    if cfg.attn_type == "full":
        # XL-style carry: previous window's keys/values (no grad). Under
        # input scanning the recurrence unit is one L-block, so the carried
        # memory is block-sized.
        h = cfg.n_heads if cfg.head_type == "mha" else 1
        mem = cfg.block_len if cfg.reduction == "inputscan" else cfg.window_len
        return {
            "prev_k": jnp.zeros((batch, h, mem, dk)),
            "prev_v": jnp.zeros((batch, h, mem, dvh)),
        }
    return {
        "cache_u": jnp.zeros((batch, hk, s, dvh)),
        "cache_l": jnp.zeros((batch, hk, s)),
        "prev_k": jnp.zeros((batch, hk, l, dk)),
        "prev_v": jnp.zeros((batch, hk, l, dvh)),
        "prev_z": jnp.zeros((batch, hk, l), dtype=jnp.int32),
    }


# ---------------------------------------------------------------------------
# VQ attention over one window (the paper's contribution)
# ---------------------------------------------------------------------------

def _fold_heads(x):
    """[B, H, ...] -> [B*H, ...]"""
    return x.reshape((-1,) + x.shape[2:])


def _proj_heads(x, w, n_heads, d_head):
    """x [B, W, Dm] @ w [Dm, H*dh] -> [B, H, W, dh]"""
    b, wlen, _ = x.shape
    y = x @ w
    return jnp.moveaxis(y.reshape(b, wlen, n_heads, d_head), 2, 1)


def vq_attention_window(
    p: Dict, cb_state: Dict, carry: Dict, has_prev: jnp.ndarray,
    x_tilde: jnp.ndarray, cfg: VQConfig,
) -> Tuple[jnp.ndarray, Dict, Dict]:
    """Compute VQ-Attention for one window of W = R*L tokens.

    Returns (o [B, W, Dv], new_carry, aux) where aux carries the commit loss
    and the (k, z) pairs for the EMA codebook update.
    """
    b, wlen, _ = x_tilde.shape
    l, s = cfg.block_len, cfg.n_code
    r = wlen // l
    h, hk = cfg.n_heads, cfg.n_kv_heads
    dk, dvh = cfg.d_k, cfg.d_v_head
    tau_rsqrt = 1.0 / math.sqrt(cfg.tau_value)

    q = rmsnorm(_proj_heads(x_tilde, p["wq"], h, dk)) * tau_rsqrt
    k = rmsnorm(_proj_heads(x_tilde, p["wk"], hk, dk)) * tau_rsqrt
    v = jax.nn.silu(_proj_heads(x_tilde, p["wv"], hk, dvh))

    # quantize keys per kv head: vq.stvq expects [..., H, D]
    k_hd = jnp.moveaxis(k, 1, 2)                       # [B, W, Hk, Dk]
    k_hat_hd, z_hd, commit = vq.stvq(k_hd, cb_state["codebook"])
    k_hat = jnp.moveaxis(k_hat_hd, 2, 1)               # [B, Hk, W, Dk]
    z = jnp.moveaxis(z_hd, 2, 1)                       # [B, Hk, W]

    # -> blocks
    qb = q.reshape(b, h, r, l, dk)
    kb = k_hat.reshape(b, hk, r, l, dk)
    vb = v.reshape(b, hk, r, l, dvh)
    zb = z.reshape(b, hk, r, l)

    # ---- cache variables (fold batch*kv-heads) --------------------------
    zf = _fold_heads(zb)                               # [Bk, R, L]
    vf = _fold_heads(vb)                               # [Bk, R, L, Dvh]
    u_blk, l_blk = red.block_summaries(zf, vf, s)
    # prepend the carried previous block's summary (guarded by has_prev)
    pz = _fold_heads(carry["prev_z"])
    pv = _fold_heads(carry["prev_v"])
    pu, plc = red.block_summaries(pz[:, None], pv[:, None], s)
    gate = jnp.repeat(has_prev, hk)[:, None, None]     # [Bk,1,1]
    plc = plc * gate
    ext_u = jnp.concatenate([pu, u_blk], axis=1)       # [Bk, R+1, S, Dvh]
    ext_l = jnp.concatenate([plc, l_blk], axis=1)
    reducer = red.REDUCTIONS["serial" if cfg.reduction == "inputscan"
                             else cfg.reduction]
    ext_cu, ext_cl = reducer(ext_u, ext_l)
    # attendable for window block n = carry.cache (+) ext_cum[n-1]
    att_u = jnp.concatenate(
        [jnp.zeros_like(ext_cu[:, :1]), ext_cu[:, :r]], axis=1)[:, :r]
    att_l = jnp.concatenate(
        [jnp.zeros_like(ext_cl[:, :1]), ext_cl[:, :r]], axis=1)[:, :r]
    cu_carry = _fold_heads(carry["cache_u"])[:, None]  # [Bk,1,S,Dvh]
    cl_carry = _fold_heads(carry["cache_l"])[:, None]
    cache_u, cache_l = red.merge_cache(
        cu_carry * jnp.ones_like(att_u), cl_carry * jnp.ones_like(att_l),
        att_u, att_l)
    if not cfg.use_cache:
        cache_u = jnp.zeros_like(cache_u)
        cache_l = jnp.zeros_like(cache_l)
    cache_lb = jnp.where(cache_l > 0.0, jnp.log(jnp.clip(cache_l, min=1.0)),
                         NEG_INF)

    # ---- prev-block keys/values -----------------------------------------
    kprev = jnp.concatenate([carry["prev_k"][:, :, None], kb[:, :, :-1]],
                            axis=2)                    # [B,Hk,R,L,Dk]
    vprev = jnp.concatenate([carry["prev_v"][:, :, None], vb[:, :, :-1]],
                            axis=2)

    # ---- positional biases (per query head) ------------------------------
    qf = _fold_heads(qb)                               # [Bh, R, L, Dk]
    rp = (sinusoid_table(2 * l, dk) @ p["wr"].reshape(dk, h * dk)) \
        .reshape(2 * l, h, dk) * tau_rsqrt
    bias_all = jnp.einsum("bhrid,ehd->bhrie", qb, rp)
    bias_all = _fold_heads(bias_all)                   # [Bh, R, L, 2L]
    bias_cur, bias_prev = gather_band_biases(bias_all, l)
    # invalidate block 0's prev attention on the first window of a sequence
    inval = (1.0 - has_prev) * NEG_INF                 # [B]
    first_blk = jnp.zeros((b, r)).at[:, 0].set(1.0)
    bias_prev = bias_prev + jnp.repeat(
        inval[:, None] * first_blk, h, axis=0)[:, :, None, None]

    # ---- broadcast kv heads to query heads & fold -------------------------
    def kv_to_qheads(x):
        if hk == h:
            return _fold_heads(x)
        xe = jnp.broadcast_to(x[:, :, None], (b, hk, h // hk) + x.shape[2:])
        return xe.reshape((b * h,) + x.shape[2:])

    kc_f = kv_to_qheads(kb)
    kp_f = kv_to_qheads(kprev)
    vc_f = kv_to_qheads(vb)
    vp_f = kv_to_qheads(vprev)
    cu_f = kv_to_qheads(cache_u.reshape((b, hk) + cache_u.shape[1:]))
    clb_f = kv_to_qheads(cache_lb.reshape((b, hk) + cache_lb.shape[1:]))
    # Codebook rows live in the same (rms-normed, tau^-0.5-scaled) space as
    # the keys — they were learned from them — so they need no extra factor.
    # Map each folded (batch, query-head) index to its kv-head's codebook.
    cb_exp = jnp.repeat(cb_state["codebook"], h // hk, axis=0)  # [H, S, Dk]
    cb_f = jnp.tile(cb_exp, (b, 1, 1))                          # [B*H, S, Dk]

    combine = combine_pallas if cfg.use_kernel else combine_jnp
    o = combine(qf, kc_f, kp_f, vc_f, vp_f, cb_f,
                cu_f, clb_f, bias_cur, bias_prev)      # [Bh, R, L, Dvh]

    o = o.reshape(b, h, wlen, dvh)
    o = jnp.moveaxis(o, 1, 2).reshape(b, wlen, h * dvh)

    # ---- new carry (stop-grad: TBPTT boundary) ---------------------------
    new_u, new_l = red.merge_cache(
        cu_carry[:, 0], cl_carry[:, 0], ext_cu[:, r - 1], ext_cl[:, r - 1])
    new_carry = {
        "cache_u": jax.lax.stop_gradient(new_u.reshape(b, hk, s, dvh)),
        "cache_l": jax.lax.stop_gradient(new_l.reshape(b, hk, s)),
        "prev_k": jax.lax.stop_gradient(kb[:, :, -1]),
        "prev_v": jax.lax.stop_gradient(vb[:, :, -1]),
        "prev_z": jax.lax.stop_gradient(zb[:, :, -1]),
    }
    aux = {"commit": commit, "k_raw": k_hd, "z": z_hd}
    return o, new_carry, aux


# ---------------------------------------------------------------------------
# full (quadratic) attention baseline with XL-style window carry
# ---------------------------------------------------------------------------

def full_attention_window(
    p: Dict, carry: Dict, has_prev: jnp.ndarray, x_tilde: jnp.ndarray,
    cfg: VQConfig,
) -> Tuple[jnp.ndarray, Dict]:
    b, wlen, _ = x_tilde.shape
    l = cfg.block_len
    h = cfg.n_heads
    hk = 1 if cfg.head_type in ("shga", "mqa") else h
    dk, dvh = cfg.d_k, cfg.d_v_head
    tau_rsqrt = 1.0 / math.sqrt(cfg.tau_value)

    q = rmsnorm(_proj_heads(x_tilde, p["wq"], h, dk)) * tau_rsqrt
    k = rmsnorm(_proj_heads(x_tilde, p["wk"], hk, dk)) * tau_rsqrt
    v = jax.nn.silu(_proj_heads(x_tilde, p["wv"], hk, dvh))

    kfull = jnp.concatenate([carry["prev_k"], k], axis=2)   # [B,Hk,2W,dk]
    vfull = jnp.concatenate([carry["prev_v"], v], axis=2)
    if hk != h:
        kfull = jnp.broadcast_to(kfull[:, :1], (b, h, 2 * wlen, dk))
        vfull = jnp.broadcast_to(vfull[:, :1], (b, h, 2 * wlen, dvh))

    # scores + causal mask over [carried window ‖ current window];
    # the mask is built from iotas, not a baked [W, 2W] constant
    scores = jnp.einsum("bhid,bhjd->bhij", q, kfull)
    ii = jax.lax.broadcasted_iota(jnp.int32, (wlen, 2 * wlen), 0) + wlen
    jj = jax.lax.broadcasted_iota(jnp.int32, (wlen, 2 * wlen), 1)
    causal = jnp.where(jj > ii, NEG_INF, 0.0)
    scores = scores + causal
    # XL-style q-dependent relative bias on the same/previous-block band
    # (matches the VQ model's B support, Theorem 3.6). Added blockwise with
    # static slices — no runtime gather (see gather_band_biases).
    phi = sinusoid_table(2 * l, dk)
    wr = p["wr"].reshape(dk, h * dk)
    rp = (phi @ wr).reshape(2 * l, h, dk) * tau_rsqrt
    r = wlen // l
    qb = q.reshape(b, h, r, l, dk)
    bias_all = jnp.einsum("bhrid,ehd->bhrie", qb, rp)       # [B,H,R,L,2L]
    bias_cur, bias_prev = gather_band_biases(
        bias_all.reshape(b * h, r, l, 2 * l), l)
    bias_cur = bias_cur.reshape(b, h, r, l, l)
    bias_prev = bias_prev.reshape(b, h, r, l, l)
    sb = scores.reshape(b, h, r, l, 2 * wlen)
    for rb in range(r):
        cur0 = wlen + rb * l
        sb = sb.at[:, :, rb, :, cur0:cur0 + l].add(bias_cur[:, :, rb])
        prev0 = wlen + (rb - 1) * l  # rb == 0 -> tail of the carried window
        sb = sb.at[:, :, rb, :, prev0:prev0 + l].add(bias_prev[:, :, rb])
    scores = sb.reshape(b, h, wlen, 2 * wlen)
    # invalidate the carried window before the first window of a sequence
    inval = (1.0 - has_prev)[:, None, None, None] * NEG_INF
    scores = scores + jnp.concatenate(
        [jnp.broadcast_to(inval, (b, 1, wlen, wlen)),
         jnp.zeros((b, 1, wlen, wlen))], axis=-1)
    m = jnp.max(scores, axis=-1, keepdims=True)
    a = jnp.exp(scores - m)
    w = a / jnp.sum(a, axis=-1, keepdims=True)
    o = jnp.einsum("bhij,bhjv->bhiv", w, vfull)
    o = jnp.moveaxis(o, 1, 2).reshape(b, wlen, h * dvh)
    new_carry = {
        "prev_k": jax.lax.stop_gradient(k),
        "prev_v": jax.lax.stop_gradient(v),
    }
    return o, new_carry


# ---------------------------------------------------------------------------
# sublayer assembly
# ---------------------------------------------------------------------------

def attn_sublayer(p, cb_state, carry, has_prev, x, cfg, rng, train):
    """Pre-norm attention sublayer with gating (SHGA) or plain output proj."""
    x_tilde = rmsnorm(x, p["ln_x"])
    aux = {"commit": jnp.zeros(()), "k_raw": None, "z": None}
    if cfg.attn_type == "vq":
        o, new_carry, aux = vq_attention_window(
            p, cb_state, carry, has_prev, x_tilde, cfg)
    else:
        o, new_carry = full_attention_window(p, carry, has_prev, x_tilde, cfg)
    if cfg.head_type == "shga":
        g = jax.nn.silu(x_tilde @ p["wg"])
        o = o * g
    o = o @ p["wo"]
    o = dropout(o, cfg.dropout_rate, rng, train)
    return x + o, new_carry, aux


def mlp_sublayer(p, x, cfg, rng, train):
    """SwiGLU MLP (only for mha/mqa head types; GAU fuses gating)."""
    h = rmsnorm(x, p["ln"])
    uv = h @ p["w1"]
    u, vv = jnp.split(uv, 2, axis=-1)
    y = (jax.nn.silu(u) * vv) @ p["w2"]
    y = dropout(y, cfg.dropout_rate, rng, train)
    return x + y
