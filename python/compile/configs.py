"""Model / artifact configuration for Transformer-VQ.

Every artifact lowered by ``aot.py`` is parameterized by a ``VQConfig``. The
rust coordinator never sees python — it reads ``artifacts/manifest.json``,
which embeds the config dict for each artifact.

Presets mirror the paper's Table 10 hyperparameters, scaled down so the CPU
PJRT backend can train them in minutes (see DESIGN.md §5 substitutions).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class VQConfig:
    """Hyperparameters of one Transformer-VQ (or baseline) model variant."""

    # -- architecture ------------------------------------------------------
    vocab_size: int = 256
    d_model: int = 64          # D_m
    d_k: int = 32              # per-head query/key width (paper: 128)
    d_v: int = 128             # total value width across heads (paper: 2*D_m)
    n_layers: int = 2          # number of attention sublayers ("num gau")
    n_heads: int = 1           # 1 => SHGA (gated, paper default)
    head_type: str = "shga"    # shga | mha | mqa
    # -- VQ attention ------------------------------------------------------
    attn_type: str = "vq"      # vq | full
    n_code: int = 64           # S, codebook size (paper: 512)
    block_len: int = 32        # L (paper: 512)
    reduction: str = "matmul"  # serial | matmul | assoc | inputscan
    use_cache: bool = True     # compressive cache (Table 2 ablation)
    use_kernel: bool = False   # route block combine through the Pallas kernel
    # -- training ----------------------------------------------------------
    window_len: int = 64       # W, backprop/update window (multiple of L)
    batch_size: int = 4        # B (global; single host here)
    commit_coef: float = 1e-4  # beta
    ema_rate: float = 0.99     # gamma, codebook EMA
    tau: float = 0.0           # 0.0 => use d_k**0.5 temperature
    dropout_rate: float = 0.0  # residual dropout (paper enwik8: 0.5)
    use_abs_pe: bool = False   # absolute sinusoid PE (paper: image datasets)
    tie_embeddings: bool = False
    # -- optimizer (AdamW; LR supplied by the rust scheduler each step) ----
    adam_b1: float = 0.9
    adam_b2: float = 0.98
    adam_eps: float = 1e-9
    weight_decay: float = 0.0
    grad_clip: float = 0.1

    def __post_init__(self) -> None:
        if self.head_type not in ("shga", "mha", "mqa"):
            raise ValueError(f"bad head_type {self.head_type}")
        if self.attn_type not in ("vq", "full"):
            raise ValueError(f"bad attn_type {self.attn_type}")
        if self.reduction not in ("serial", "matmul", "assoc", "inputscan"):
            raise ValueError(f"bad reduction {self.reduction}")
        if self.window_len % self.block_len != 0:
            raise ValueError("window_len must be a multiple of block_len")
        if self.d_v % max(self.n_heads, 1) != 0:
            raise ValueError("d_v must divide n_heads")
        if self.head_type == "shga" and self.n_heads != 1:
            raise ValueError("shga is single-head")

    # ------------------------------------------------------------------
    @property
    def tau_value(self) -> float:
        """Attention temperature: scores are divided by tau (paper eq. 8-9)."""
        return self.tau if self.tau > 0 else float(self.d_k) ** 0.5

    @property
    def n_kv_heads(self) -> int:
        return 1 if self.head_type in ("shga", "mqa") else self.n_heads

    @property
    def d_v_head(self) -> int:
        return self.d_v // self.n_heads

    @property
    def blocks_per_window(self) -> int:
        return self.window_len // self.block_len

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict) -> "VQConfig":
        return VQConfig(**d)

    def replace(self, **kw) -> "VQConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Presets. Naming: <track>-<size>. All are CPU-trainable scaled versions of the
# paper's Table 10 rows; relative shapes (d_v = 2*d_m, d_k fixed & small,
# L = S, W = 4L where feasible) are preserved.
# ---------------------------------------------------------------------------

def _presets() -> Dict[str, VQConfig]:
    p: Dict[str, VQConfig] = {}

    # Byte-level LM (enwik8 stand-in). ~1.6M params.
    p["enwik8-tiny"] = VQConfig(
        vocab_size=256, d_model=128, d_k=32, d_v=256, n_layers=4,
        n_code=64, block_len=32, window_len=128, batch_size=8,
        reduction="matmul", use_kernel=False,
    )
    # Smoke-test sized, used by quickstart + integration tests. ~120k params.
    p["quickstart"] = VQConfig(
        vocab_size=256, d_model=64, d_k=16, d_v=128, n_layers=2,
        n_code=32, block_len=16, window_len=64, batch_size=4,
        reduction="matmul", use_kernel=True,
    )
    # Open-vocab LM (PG-19 stand-in), BPE vocab from the rust tokenizer.
    p["pg19-tiny"] = VQConfig(
        vocab_size=1024, d_model=128, d_k=32, d_v=256, n_layers=4,
        n_code=64, block_len=32, window_len=128, batch_size=8,
        reduction="matmul",
    )
    # Flattened-image density modeling (ImageNet64 stand-in).
    p["imagenet64-tiny"] = VQConfig(
        vocab_size=256, d_model=128, d_k=32, d_v=256, n_layers=4,
        n_code=64, block_len=32, window_len=128, batch_size=4,
        use_abs_pe=True, reduction="matmul",
    )

    # Table 1 codebook-size ablation: S in {64, 128, 256} (paper {256,512,1024})
    for s in (32, 64, 128):
        p[f"ablate-S{s}"] = p["enwik8-tiny"].replace(n_code=s)
    # Table 2 compressive-cache ablation (paper used S=256 -> our S=32).
    p["ablate-nocache"] = p["enwik8-tiny"].replace(n_code=32, use_cache=False)
    p["ablate-cache"] = p["enwik8-tiny"].replace(n_code=32, use_cache=True)
    return p


PRESETS: Dict[str, VQConfig] = _presets()


def throughput_grid(
    seq_lens: Optional[List[int]] = None,
    head_types: Optional[List[str]] = None,
    variants: Optional[List[str]] = None,
) -> Dict[str, VQConfig]:
    """Benchmark grid for paper Tables 6-9 (Full vs VQ throughput).

    Variant names: full, full-inputscan, vq-serial, vq-matmul, vq-assoc,
    vq-inputscan. Sequence lengths are scaled 8x down from the paper's
    {2048..131072} to {256..16384} (CPU backend); the scaling *exponent*
    of quadratic vs linear attention is unchanged.
    """
    seq_lens = seq_lens or [256, 1024, 4096]
    head_types = head_types or ["shga", "mqa", "mha"]
    variants = variants or ["full", "vq-serial", "vq-matmul", "vq-assoc",
                            "vq-inputscan", "full-inputscan"]
    grid: Dict[str, VQConfig] = {}
    for t in seq_lens:
        for h in head_types:
            for v in variants:
                attn = "full" if v.startswith("full") else "vq"
                red = v.split("-", 1)[1] if "-" in v else "matmul"
                if attn == "full" and red == "full":
                    red = "matmul"
                n_heads = 1 if h == "shga" else 4
                grid[f"tput-{h}-{v}-T{t}"] = VQConfig(
                    vocab_size=256, d_model=64, d_k=16, d_v=128, n_layers=2,
                    n_heads=n_heads, head_type=h, attn_type=attn,
                    n_code=64, block_len=64, window_len=t, batch_size=1,
                    reduction=red if red in ("serial", "matmul", "assoc",
                                             "inputscan") else "matmul",
                )
    return grid


def config_json(cfg: VQConfig) -> str:
    return json.dumps(cfg.to_dict(), indent=2, sort_keys=True)
