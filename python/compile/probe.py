"""Compatibility probe: verify HLO ops execute correctly under the rust
PJRT runtime (xla_extension 0.5.1), which predates jax 0.8's lowering by
~3 years. Some gather/scatter forms miscompile there (see DESIGN.md
§Runtime-compat); this harness catches regressions whenever the lowering
patterns change.

Usage:
  python -m compile.probe emit /tmp/probes     # write hlo+inputs+expected
  <run rust:  runhlo <hlo> <in.tvq> <got.tvq>  for each probe>
  python -m compile.probe check /tmp/probes    # compare
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import tvq
from .aot import to_hlo_text


def _rand(key, shape, dtype=jnp.float32, hi=None):
    if dtype == jnp.int32:
        return jax.random.randint(key, shape, 0, hi or 8)
    return jax.random.normal(key, shape, dtype)


def build_probes():
    """name -> (fn, args). Functions must be deterministic."""
    k = jax.random.split(jax.random.PRNGKey(0), 24)
    probes = {}

    # embedding lookup (classic gather)
    probes["embed_lookup"] = (
        lambda emb, idx: (emb[idx],),
        (_rand(k[0], (16, 8)), _rand(k[1], (4, 6), jnp.int32, 16)),
    )
    # take_along_axis depth-3 (the CE-loss gather shape)
    probes["take_along3"] = (
        lambda x, i: (jnp.take_along_axis(x, i[..., None], axis=-1),),
        (_rand(k[2], (2, 6, 9)), _rand(k[3], (2, 6), jnp.int32, 9)),
    )
    # take_along_axis depth-4 (the band-bias gather shape)
    probes["take_along4"] = (
        lambda x, i: (jnp.take_along_axis(x, i, axis=-1),),
        (_rand(k[4], (2, 3, 4, 10)), _rand(k[5], (2, 3, 4, 4), jnp.int32, 10)),
    )
    # one-hot matmul alternative to gather
    probes["onehot_matmul"] = (
        lambda emb, idx: (jnp.einsum("btv,vd->btd",
                                     jax.nn.one_hot(idx, emb.shape[0]), emb),),
        (_rand(k[6], (16, 8)), _rand(k[7], (4, 6), jnp.int32, 16)),
    )
    # scatter-add via bincount
    probes["bincount"] = (
        lambda z: (jnp.bincount(z, length=16).astype(jnp.float32),),
        (_rand(k[8], (64,), jnp.int32, 16),),
    )
    # cumsum / scan
    probes["cumsum"] = (
        lambda x: (jnp.cumsum(x, axis=1),),
        (_rand(k[9], (3, 7, 2)),),
    )
    # .at[].set one-hot write (decode path)
    probes["at_set"] = (
        lambda x, v: (x.at[:, 3].set(v),),
        (_rand(k[10], (4, 8)), _rand(k[11], (4,))),
    )
    # dynamic_update_slice-free masked write (decode path)
    def masked_write(win, val, p):
        slot = jax.nn.one_hot(p, win.shape[1], dtype=win.dtype)
        return (win * (1 - slot[..., None]) + val[:, None, :] * slot[..., None],)
    probes["masked_write"] = (
        masked_write,
        (_rand(k[12], (2, 8, 4)), _rand(k[13], (2, 4)),
         _rand(k[14], (2,), jnp.int32, 8)),
    )
    # table row gather with clipped indices (decode positional bias)
    probes["table_rows"] = (
        lambda t, p: (t[jnp.clip(p, 0, t.shape[0] - 1)],),
        (_rand(k[15], (32, 8)), _rand(k[16], (5,), jnp.int32, 32)),
    )
    # argmin + one_hot codebook gather (vq path)
    def vq_assign(kk, cb):
        d = jnp.sum(cb * cb, -1) - 2.0 * kk @ cb.T
        z = jnp.argmin(d, -1)
        return (jax.nn.one_hot(z, cb.shape[0]) @ cb, z.astype(jnp.int32))
    probes["vq_assign"] = (vq_assign, (_rand(k[17], (6, 4)), _rand(k[18], (9, 4))))
    return probes


def emit(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, args) in build_probes().items():
        with open(f"{out_dir}/{name}.hlo.txt", "w") as f:
            f.write(to_hlo_text(fn, *args))
        tvq.write(f"{out_dir}/{name}.in.tvq",
                  [(f"arg{i}", np.asarray(a)) for i, a in enumerate(args)])
        out = fn(*args)
        tvq.write(f"{out_dir}/{name}.expected.tvq",
                  [(f"out{i}", np.asarray(o)) for i, o in enumerate(out)])
    print(f"emitted {len(build_probes())} probes to {out_dir}")


def check(out_dir: str) -> int:
    failures = 0
    for name in build_probes():
        got_path = f"{out_dir}/{name}.got.tvq"
        if not os.path.exists(got_path):
            print(f"MISSING {name} (run runhlo first)")
            failures += 1
            continue
        want = tvq.read(f"{out_dir}/{name}.expected.tvq")
        got = tvq.read(got_path)
        ok = len(want) == len(got)
        if ok:
            for (_, w), (_, g) in zip(want, got):
                if w.shape != g.shape or not np.allclose(
                        w.astype(np.float64), g.astype(np.float64),
                        atol=1e-5, rtol=1e-5):
                    ok = False
        print(f"{'OK  ' if ok else 'FAIL'} {name}")
        failures += 0 if ok else 1
    return failures


if __name__ == "__main__":
    mode, out_dir = sys.argv[1], sys.argv[2]
    if mode == "emit":
        emit(out_dir)
    else:
        sys.exit(check(out_dir))
