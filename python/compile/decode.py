"""Token-level autoregressive decoding with the compressive cache.

§4.1 of the paper notes that VQ-Attention's cache update can be applied every
token instead of every L tokens, so sampling needs no sporadic feature
consolidation. This module implements that: per layer the decoder keeps

  k_win [B, Hk, 2L, Dk]  quantized keys — slots [0,L) = previous block,
                         slots [L, 2L) = current partial block
  v_win [B, Hk, 2L, Dvh]
  z_win [B, Hk, 2L] i32  shortcodes (so a completed block can be folded)
  cache_u [B, Hk, S, Dvh], cache_l [B, Hk, S]   compressive cache

plus one model-level position counter ``pos [B] i32``. At a block boundary
(pos % L == 0) the oldest block is folded into the cache (running-mean merge)
and the window shifts — all expressed with masks/where so the step lowers to
a single static HLO module. Per-token cost is O(S + 2L), i.e. generation of
T tokens is O(T) (linear-time sampling, Conclusion §6).

The rust sampler (L3) owns the state tensors, performs nucleus sampling on
the returned logits, and feeds tokens back in.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import VQConfig
from . import layers, model
from .kernels import vq
from .kernels.vq_attn import NEG_INF


def init_decode_state(cfg: VQConfig, batch: int) -> Dict:
    hk, s, l = cfg.n_kv_heads, cfg.n_code, cfg.block_len
    return {
        "layers": [
            {
                "k_win": jnp.zeros((batch, hk, 2 * l, cfg.d_k)),
                "v_win": jnp.zeros((batch, hk, 2 * l, cfg.d_v_head)),
                "z_win": jnp.zeros((batch, hk, 2 * l), dtype=jnp.int32),
                "cache_u": jnp.zeros((batch, hk, s, cfg.d_v_head)),
                "cache_l": jnp.zeros((batch, hk, s)),
            }
            for _ in range(cfg.n_layers)
        ],
        "pos": jnp.zeros((batch,), dtype=jnp.int32),
    }


def _fold_and_shift(st: Dict, pos, cfg: VQConfig) -> Dict:
    """At block boundaries: fold window slots [0,L) into the cache and shift
    [L,2L) down. Gated by masks so the graph is static."""
    l, s = cfg.block_len, cfg.n_code
    p = pos % l                                       # [B]
    boundary = (p == 0) & (pos >= 2 * l)              # fold is meaningful
    shift = (p == 0) & (pos >= l)                     # prev block exists

    zb = st["z_win"][:, :, :l]                        # [B,Hk,L]
    vb = st["v_win"][:, :, :l]
    onehot = jax.nn.one_hot(zb, s, dtype=vb.dtype)    # [B,Hk,L,S]
    cnt = jnp.einsum("bhls->bhs", onehot)
    sums = jnp.einsum("bhls,bhlv->bhsv", onehot, vb)
    cnt = cnt * boundary[:, None, None].astype(vb.dtype)
    sums = sums * boundary[:, None, None, None].astype(vb.dtype)
    u_blk = sums / jnp.clip(cnt[..., None], min=1.0)

    l_new = st["cache_l"] + cnt
    f1 = st["cache_l"] / jnp.clip(l_new, min=1.0)
    f2 = cnt / jnp.clip(l_new, min=1.0)
    cache_u = f1[..., None] * st["cache_u"] + f2[..., None] * u_blk
    cache_l = l_new

    do_shift = shift[:, None, None, None]
    zeros_k = jnp.zeros_like(st["k_win"][:, :, :l])
    k_win = jnp.where(do_shift, jnp.concatenate(
        [st["k_win"][:, :, l:], zeros_k], axis=2), st["k_win"])
    v_win = jnp.where(do_shift, jnp.concatenate(
        [st["v_win"][:, :, l:], jnp.zeros_like(st["v_win"][:, :, :l])],
        axis=2), st["v_win"])
    z_win = jnp.where(shift[:, None, None], jnp.concatenate(
        [st["z_win"][:, :, l:], jnp.zeros_like(st["z_win"][:, :, :l])],
        axis=2), st["z_win"])
    return {"k_win": k_win, "v_win": v_win, "z_win": z_win,
            "cache_u": cache_u, "cache_l": cache_l}


def _decode_attn(p: Dict, cb_state: Dict, st: Dict, pos, x: jnp.ndarray,
                 cfg: VQConfig) -> Tuple[jnp.ndarray, Dict]:
    """One token through one VQ-attention sublayer. x [B, Dm]."""
    b, _ = x.shape
    l, s = cfg.block_len, cfg.n_code
    h, hk = cfg.n_heads, cfg.n_kv_heads
    dk, dvh = cfg.d_k, cfg.d_v_head
    tau_rsqrt = 1.0 / math.sqrt(cfg.tau_value)

    st = _fold_and_shift(st, pos, cfg)
    p_idx = pos % l                                   # [B]

    x_t = layers.rmsnorm(x, p["ln_x"])
    q = layers.rmsnorm(
        (x_t @ p["wq"]).reshape(b, h, dk)) * tau_rsqrt
    k = layers.rmsnorm(
        (x_t @ p["wk"]).reshape(b, hk, dk)) * tau_rsqrt
    v = jax.nn.silu((x_t @ p["wv"]).reshape(b, hk, dvh))
    k_hat, z, _ = vq.stvq(k, cb_state["codebook"])    # [B,Hk,dk], [B,Hk]

    # write into slot L + p_idx (one-hot write, vectorized over batch)
    slot = jax.nn.one_hot(l + p_idx, 2 * l)           # [B, 2L]
    wmask = slot[:, None, :, None]
    k_win = st["k_win"] * (1 - wmask) + k_hat[:, :, None, :] * wmask
    v_win = st["v_win"] * (1 - wmask) + v[:, :, None, :] * wmask
    z_win = jnp.where(slot[:, None, :].astype(bool),
                      z[:, :, None], st["z_win"])

    # ---- scores -----------------------------------------------------------
    jj = jnp.arange(2 * l)[None, :]                   # [1, 2L]
    valid_prev = (jj < l) & (pos[:, None] >= l)
    valid_cur = (jj >= l) & (jj <= l + p_idx[:, None])
    valid = valid_prev | valid_cur                    # [B, 2L]
    d = l + p_idx[:, None] - jj                       # distance, [B, 2L]
    d_clip = jnp.clip(d, 0, 2 * l - 1)

    phi = layers.sinusoid_table(2 * l, dk)
    rp = (phi @ p["wr"].reshape(dk, h * dk)).reshape(2 * l, h, dk) * tau_rsqrt
    bias_all = jnp.einsum("bhd,ehd->bhe", q, rp)      # [B,H,2L]
    # one-hot contraction instead of take_along_axis (runtime compat,
    # probe.py): bias[b,h,j] = bias_all[b,h,d_clip[b,j]]
    d_onehot = jax.nn.one_hot(d_clip, 2 * l, dtype=bias_all.dtype)  # [B,2L,2L]
    bias = jnp.einsum("bhe,bje->bhj", bias_all, d_onehot)
    bias = jnp.where(valid[:, None], bias, NEG_INF)

    def kv_b(t):  # [B,Hk,...] -> [B,H,...]
        if hk == h:
            return t
        return jnp.broadcast_to(t[:, :1], (b, h) + t.shape[2:])

    s_win = jnp.einsum("bhd,bhjd->bhj", q, kv_b(k_win)) + bias
    cb_rows = jnp.repeat(cb_state["codebook"], h // hk, axis=0)  # [H,S,dk]
    lb = jnp.where(st["cache_l"] > 0,
                   jnp.log(jnp.clip(st["cache_l"], min=1.0)), NEG_INF)
    s_cache = jnp.einsum("bhd,hsd->bhs", q, cb_rows) + kv_b(lb)
    if not cfg.use_cache:
        s_cache = jnp.full_like(s_cache, NEG_INF)

    m = jnp.maximum(jnp.max(s_win, axis=-1), jnp.max(s_cache, axis=-1))
    a_win = jnp.exp(s_win - m[..., None])
    a_cache = jnp.exp(s_cache - m[..., None])
    denom = jnp.sum(a_win, axis=-1) + jnp.sum(a_cache, axis=-1)
    o = jnp.einsum("bhj,bhjv->bhv", a_win, kv_b(v_win))
    o += jnp.einsum("bhs,bhsv->bhv", a_cache, kv_b(st["cache_u"]))
    o = (o / denom[..., None]).reshape(b, h * dvh)

    if cfg.head_type == "shga":
        o = o * jax.nn.silu(x_t @ p["wg"])
    o = o @ p["wo"]

    new_st = {"k_win": k_win, "v_win": v_win, "z_win": z_win,
              "cache_u": st["cache_u"], "cache_l": st["cache_l"]}
    return x + o, new_st


def decode_step(params: Dict, cb_states: List[Dict], state: Dict,
                token: jnp.ndarray, cfg: VQConfig):
    """One decoding step. token [B] i32 -> (logits [B, V], new_state)."""
    pos = state["pos"]
    x = params["embed"][token]                        # [B, Dm]
    if cfg.use_abs_pe:
        x = x + params["pe_scale"] * layers.sinusoid_at(pos, cfg.d_model)
    new_layers = []
    for i, lp in enumerate(params["layers"]):
        x, st = _decode_attn(lp["attn"], cb_states[i], state["layers"][i],
                             pos, x, cfg)
        if "mlp" in lp:
            x = layers.mlp_sublayer(lp["mlp"], x[:, None], cfg, None,
                                    False)[:, 0]
        new_layers.append(st)
    logits = model._logits(params, cfg, x[:, None])[:, 0]
    return logits, {"layers": new_layers, "pos": pos + 1}
