"""Vector quantization with straight-through estimator + EMA k-means codebook.

Implements Definition 2.6 (STVQ) and the van den Oord / Razavi EMA codebook
update used by the paper (Appendix C: commit coefficient beta=1e-4, EMA rate
gamma=0.99). Codebooks receive no gradient; they are updated by exponential
moving averages of assignment counts and assigned-key sums, with Laplace
smoothing of the counts.

Shapes use H = number of key heads (1 for SHGA/MQA), S = codebook size,
D = d_k per head.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def nearest_code(k: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Shortcodes z = argmin_s ||k - C_s||^2.

    k: [..., H, D] (any leading dims), codebook: [H, S, D] -> z: [..., H] int32.

    Uses the expanded form ||k||^2 - 2 k.C + ||C||^2; the ||k||^2 term is
    constant w.r.t. s and omitted.
    """
    # scores[..., h, s] = -2 k.C_s + ||C_s||^2
    dots = jnp.einsum("...hd,hsd->...hs", k, codebook)
    c_sq = jnp.sum(jnp.square(codebook), axis=-1)  # [H, S]
    dist = c_sq - 2.0 * dots
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def stvq(
    k: jnp.ndarray, codebook: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Straight-through vector quantization (Definition 2.6).

    Returns (k_hat, z, commit_loss) where commit_loss is the *mean over all
    quantized vectors* of ||k - sg(C_z)||^2 (eq. 37 divided by token count;
    the caller scales by beta and sums over layers).
    """
    z = nearest_code(k, codebook)
    quantized = _gather_codes(codebook, z)
    k_hat = k + jax.lax.stop_gradient(quantized - k)
    commit = jnp.mean(
        jnp.sum(jnp.square(k - jax.lax.stop_gradient(quantized)), axis=-1)
    )
    return k_hat, z, commit


def _gather_codes(codebook: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """codebook: [H, S, D], z: [..., H] -> [..., H, D]."""
    h = codebook.shape[0]
    one_hot = jax.nn.one_hot(z, codebook.shape[1], dtype=codebook.dtype)
    # [..., H, S] x [H, S, D] -> [..., H, D]
    del h
    return jnp.einsum("...hs,hsd->...hd", one_hot, codebook)


def codebook_init(key: jax.Array, n_heads: int, n_code: int, d: int,
                  scale: float = 1.0) -> Dict:
    """Fresh EMA codebook state.

    ``codebook`` is materialized from the EMA statistics so that the state is
    self-consistent: codebook = ema_sum / smoothed(ema_count). ``scale``
    should match the per-dim std of the keys being quantized (the model
    rms-normalizes keys then multiplies by tau^-0.5, so their per-dim std is
    ~tau^-0.5) — a mismatched init collapses early assignments onto a few
    codes and the EMA takes thousands of steps to recover.
    """
    c = jax.random.normal(key, (n_heads, n_code, d)) * scale
    return {
        "codebook": c,
        "ema_count": jnp.ones((n_heads, n_code)),
        "ema_sum": c,  # consistent with count == 1
    }


def ema_update(
    state: Dict, k: jnp.ndarray, z: jnp.ndarray, gamma: float, eps: float = 1e-5
) -> Dict:
    """EMA k-means codebook update (Razavi et al. 2019, eqs. in App. A).

    k: [..., H, D] raw (unquantized) keys, z: [..., H] shortcodes. All leading
    dims are flattened into the batch of assignments. Gradients are stopped:
    codebooks are parameterized purely by the EMAs.
    """
    k = jax.lax.stop_gradient(k)
    n_heads, n_code, _ = state["codebook"].shape
    kf = k.reshape((-1, n_heads, k.shape[-1]))          # [T*, H, D]
    zf = z.reshape((-1, n_heads))                       # [T*, H]
    one_hot = jax.nn.one_hot(zf, n_code, dtype=kf.dtype)  # [T*, H, S]
    counts = jnp.einsum("ths->hs", one_hot)
    sums = jnp.einsum("ths,thd->hsd", one_hot, kf)
    new_count = gamma * state["ema_count"] + (1.0 - gamma) * counts
    new_sum = gamma * state["ema_sum"] + (1.0 - gamma) * sums
    # Laplace smoothing keeps dead codes near the data mean instead of NaN.
    total = jnp.sum(new_count, axis=-1, keepdims=True)
    smoothed = (new_count + eps) / (total + n_code * eps) * total
    codebook = new_sum / smoothed[..., None]
    return {"codebook": codebook, "ema_count": new_count, "ema_sum": new_sum}


def codebook_perplexity(z: jnp.ndarray, n_code: int) -> jnp.ndarray:
    """exp(entropy) of the empirical shortcode distribution — a measure of
    codebook utilization (S means uniform use, 1 means collapse)."""
    zf = z.reshape((-1,))
    counts = jnp.bincount(zf, length=n_code).astype(jnp.float32)
    probs = counts / jnp.maximum(jnp.sum(counts), 1.0)
    ent = -jnp.sum(jnp.where(probs > 0, probs * jnp.log(probs), 0.0))
    return jnp.exp(ent)
