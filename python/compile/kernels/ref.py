"""Pure-jnp quadratic oracles for correctness testing.

These implement Definition 3.1 directly (materializing the full T x T score
matrix) and serve as ground truth for:

  * the linear-time block recurrence (Theorem 3.7 exactness),
  * the Pallas kernel (python/tests/test_kernel.py),
  * the decode-time cache roll (python/tests/test_decode.py),
  * golden values exported for the rust test-suite.

Everything here is deliberately naive and O(T^2); nothing in this module is
ever lowered into a shipped artifact.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

NEG_INF = -1e30


def quadratic_attention(q, k, v, bias):
    """softmax(q k^T + bias) v over full sequences.

    q [B,T,Dk], k [B,T,Dk], v [B,T,Dv], bias [B,T,T] (additive; caller bakes
    causal mask / window structure / NEG_INF invalidations into it).
    """
    scores = jnp.einsum("bid,bjd->bij", q, k) + bias
    m = jnp.max(scores, axis=-1, keepdims=True)
    a = jnp.exp(scores - m)
    w = a / jnp.sum(a, axis=-1, keepdims=True)
    return jnp.einsum("bij,bjv->biv", w, v)


def banded_bias_matrix(bias_all, block_len, t):
    """Expand per-distance q-dependent biases into the paper's blocked band.

    bias_all [B,T,2L]: bias_all[b,i,d] is the bias for query i attending at
    distance d (0 <= d < 2L). Bias applies only when key j is in the same or
    previous block as query i (the paper's B has support on that band);
    outside the band but causally visible => bias 0 (cache region); j > i =>
    NEG_INF.
    Returns [B,T,T].
    """
    b = bias_all.shape[0]
    i = np.arange(t)[:, None]
    j = np.arange(t)[None, :]
    d = i - j
    same_or_prev = (i // block_len - j // block_len) <= 1
    causal = d >= 0
    band = causal & same_or_prev
    d_clip = np.clip(d, 0, bias_all.shape[-1] - 1)
    gathered = jnp.take_along_axis(
        bias_all, jnp.asarray(np.broadcast_to(d_clip, (b, t, t))), axis=-1
    )
    out = jnp.where(jnp.asarray(band), gathered, 0.0)
    out = jnp.where(jnp.asarray(causal), out, NEG_INF)
    return out


def vq_attention_quadratic(q, k_hat, v, bias_all, block_len):
    """Ground truth for VQ-Attention: dense quadratic attention over the
    *quantized* keys with the blocked-band positional bias (Definition 3.1
    with B as in Theorem 3.6). The linear-time recurrence must match this
    bit-for-bit up to float assoc error."""
    t = q.shape[1]
    bias = banded_bias_matrix(bias_all, block_len, t)
    return quadratic_attention(q, k_hat, v, bias)


def naive_cache_vars(z, v, n_code):
    """O(T*S) python-loop reference for the cross-block reductions.

    z [B,R,L] int, v [B,R,L,Dv] -> (u_cum [B,R,S,Dv] running mean through
    block r, l_cum [B,R,S] running count)."""
    z = np.asarray(z)
    v = np.asarray(v)
    b, r, l = z.shape
    dv = v.shape[-1]
    u = np.zeros((b, r, n_code, dv), dtype=np.float64)
    c = np.zeros((b, r, n_code), dtype=np.float64)
    for bi in range(b):
        sums = np.zeros((n_code, dv))
        counts = np.zeros((n_code,))
        for ri in range(r):
            for li in range(l):
                s = z[bi, ri, li]
                sums[s] += v[bi, ri, li]
                counts[s] += 1
            c[bi, ri] = counts
            u[bi, ri] = sums / np.clip(counts, 1.0, None)[:, None]
    return u.astype(v.dtype), c.astype(v.dtype)


def naive_quantize(k, codebook):
    """Nearest-neighbour assignment, numpy loops. k [...,D], cb [S,D]."""
    k = np.asarray(k)
    cb = np.asarray(codebook)
    flat = k.reshape(-1, k.shape[-1])
    z = np.empty(flat.shape[0], dtype=np.int32)
    for i, row in enumerate(flat):
        z[i] = int(np.argmin(((row[None, :] - cb) ** 2).sum(-1)))
    return z.reshape(k.shape[:-1])
