"""Cross-block reductions computing the compressive-cache variables.

These are the three numerically-stabilized generalizations of FLASH's
cross-block reductions from Appendix B / Appendix E of the paper:

  * ``serial``  — ``jax.lax.scan`` over blocks (Code 2)
  * ``matmul``  — lower-triangular matmul against block summaries (Code 3)
  * ``assoc``   — ``jax.lax.associative_scan`` with a weighted-mean merge
                  (Code 4)

All three return, for every block index n, the *running mean* of value
vectors per shortcode over blocks <= n-2 (``cache_u``, shape [B,R,S,Dv]) and
the running count (``cache_l``, shape [B,R,S]). Storing means instead of sums
(Remark 3.9) keeps the magnitudes bounded; the attention combine re-weights
by moving log-counts into the exponent.

Inputs: z [B,R,L] int32 shortcodes, v [B,R,L,Dv] values, n_code S.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def block_summaries(
    z: jnp.ndarray, v: jnp.ndarray, n_code: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block grouped means and counts.

    Returns (u_blk [B,R,S,Dv] mean of v per code within each block,
             l_blk [B,R,S] count per code within each block).
    """
    delta = jax.nn.one_hot(z, n_code, dtype=v.dtype)       # [B,R,L,S]
    l_blk = jnp.einsum("brls->brs", delta)                 # [B,R,S]
    uv_blk = jnp.einsum("brls,brlv->brsv", delta, v)       # [B,R,S,Dv]
    u_blk = uv_blk / jnp.clip(l_blk[..., None], min=1.0)
    return u_blk, l_blk


def shift2(u_cum: jnp.ndarray, l_cum: jnp.ndarray):
    """Shift cumulative-through-block-n stats to 'blocks <= n-2' alignment.

    Block n's attendable cache covers blocks <= n-2 (block n-1 is attended
    directly with positional biases; see Theorem 3.7).
    """
    u = jnp.pad(u_cum[:, :-2], ((0, 0), (2, 0), (0, 0), (0, 0)))
    l = jnp.pad(l_cum[:, :-2], ((0, 0), (2, 0), (0, 0)))
    return u, l


def reduce_serial(u_blk, l_blk):
    """Code 2: sequential scan over blocks carrying (mean, count)."""

    def scan_fn(carry, inp):
        u, l = carry
        u_b, l_b = inp
        l_new = l + l_b
        f1 = l / jnp.clip(l_new, min=1.0)
        f2 = l_b / jnp.clip(l_new, min=1.0)
        u_new = f1[..., None] * u + f2[..., None] * u_b
        return (u_new, l_new), (u_new, l_new)

    u0 = jnp.zeros_like(u_blk[:, 0])
    l0 = jnp.zeros_like(l_blk[:, 0])
    u_t = jnp.moveaxis(u_blk, 1, 0)  # scan axis first
    l_t = jnp.moveaxis(l_blk, 1, 0)
    _, (u_cum, l_cum) = jax.lax.scan(scan_fn, (u0, l0), (u_t, l_t))
    return jnp.moveaxis(u_cum, 0, 1), jnp.moveaxis(l_cum, 0, 1)


def reduce_matmul(u_blk, l_blk):
    """Code 3: cumulative grouped means via a masked matmul.

    The cumulative mean through block r is
        sum_{g<=r} l_g * u_g / sum_{g<=r} l_g,
    computed as a matmul of per-block normalized summaries against
    count-fraction weights, which is the stabilized form of FLASH's
    lower-triangular-ones matmul.
    """
    # tiled[b,s,r,g] = l_blk[b,g,s] for g <= r else 0
    tiled = jnp.einsum("brs,bgs->bsrg", jnp.ones_like(l_blk), l_blk)
    tiled = jnp.tril(tiled)
    denom = jnp.clip(jnp.sum(tiled, axis=-1, keepdims=True), min=1.0)
    fracs = tiled / denom                                   # [B,S,R,G]
    u_cum = jnp.einsum("bsrg,bgsv->brsv", fracs, u_blk)
    l_cum = jnp.cumsum(l_blk, axis=1)
    return u_cum, l_cum


def reduce_assoc(u_blk, l_blk):
    """Code 4: parallel prefix scan with the weighted-mean monoid."""

    def merge(a, b):
        u_a, l_a = a
        u_b, l_b = b
        l_new = l_a + l_b
        t1 = (l_a / jnp.clip(l_new, min=1.0))[..., None] * u_a
        t2 = (l_b / jnp.clip(l_new, min=1.0))[..., None] * u_b
        return t1 + t2, l_new

    return jax.lax.associative_scan(merge, (u_blk, l_blk), axis=1)


REDUCTIONS = {
    "serial": reduce_serial,
    "matmul": reduce_matmul,
    "assoc": reduce_assoc,
    # "inputscan" is not a cache-vars reduction: it scans whole layer inputs
    # block-by-block (see model.py) and uses the serial merge incrementally.
}


def get_cache_vars(z, v, n_code, method: str):
    """Cumulative (mean, count) through each block n (UNshifted; apply
    ``shift2`` to obtain the attendable cache for each block).

    Convenience wrapper over ``REDUCTIONS[method]`` which operate directly on
    per-block (mean, count) summaries — the model prepends the TBPTT-carried
    previous-block summary before reducing, see layers.py."""
    if method == "inputscan":
        method = "serial"
    return REDUCTIONS[method](*block_summaries(z, v, n_code))


def merge_cache(u_a, l_a, u_b, l_b):
    """Merge two (mean, count) cache aggregates (used for the TBPTT carry)."""
    l_new = l_a + l_b
    t1 = (l_a / jnp.clip(l_new, min=1.0))[..., None] * u_a
    t2 = (l_b / jnp.clip(l_new, min=1.0))[..., None] * u_b
    return t1 + t2, l_new
