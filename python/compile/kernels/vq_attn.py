"""VQ-Attention block combine: Pallas kernel + jnp twin.

This is the compute hot-spot of the paper (Theorem 3.7 / Appendix E Code 1):
for each query block n, merge three score groups under one numerically-stable
softmax —

  * ``cache``   — scores against the codebook ``q @ C^T`` plus log-count
                  biases (attends the compressive cache U(n-2)/L(n-2));
  * ``prev``    — exact banded attention to block n-1 with positional biases;
  * ``present`` — causally-masked attention within block n.

Inputs arrive pre-aligned (the model shifts prev blocks / cache vars and bakes
the causal mask, block-0 invalidation and log-count biases into the bias
tensors), so the kernel body is uniform across grid cells — no data-dependent
control flow, which is exactly what the TPU MXU wants.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid is (batch*heads,
num_blocks); each grid cell loads one L-block of q/k/v plus the S-row codebook
and cache into VMEM (~L*Dk + 2L*(Dk+Dv) + S*(Dk+Dv) floats) and issues
MXU-shaped matmuls (L x Dk x L, L x Dk x S, L x S x Dv). On this image the
kernel runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); interpret mode has no reverse-mode AD, so the public entry
point wraps the kernel in ``jax.custom_vjp`` whose backward pass is the VJP of
the jnp twin (same math; equality is asserted in python/tests/test_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# jnp twin — differentiable, single source of truth for the math
# ---------------------------------------------------------------------------

def combine_jnp(q, khat_cur, khat_prev, v_cur, v_prev, codebook,
                cache_u, cache_lb, bias_cur, bias_prev):
    """Stable three-way softmax attention combine.

    Shapes (Bf folds batch*query-heads, R blocks, L block length):
      q          [Bf, R, L, Dk]
      khat_cur   [Bf, R, L, Dk]   quantized keys of block n
      khat_prev  [Bf, R, L, Dk]   quantized keys of block n-1 (shifted in)
      v_cur      [Bf, R, L, Dv]
      v_prev     [Bf, R, L, Dv]
      codebook   [Bf, S, Dk]      per-(folded)batch codebook rows
      cache_u    [Bf, R, S, Dv]   running per-code value means over blocks<=n-2
      cache_lb   [Bf, R, S]       log counts (NEG_INF where count == 0)
      bias_cur   [Bf, R, L, L]    positional bias + causal mask (NEG_INF)
      bias_prev  [Bf, R, L, L]    positional bias + block-0 invalidation
    Returns o [Bf, R, L, Dv].
    """
    s_cur = jnp.einsum("brid,brjd->brij", q, khat_cur) + bias_cur
    s_prev = jnp.einsum("brid,brjd->brij", q, khat_prev) + bias_prev
    s_cache = jnp.einsum("brid,bsd->bris", q, codebook) + cache_lb[:, :, None, :]

    m = jnp.maximum(
        jnp.maximum(jnp.max(s_cur, axis=-1), jnp.max(s_prev, axis=-1)),
        jnp.max(s_cache, axis=-1),
    )
    m = jax.lax.stop_gradient(m)[..., None]
    a_cur = jnp.exp(s_cur - m)
    a_prev = jnp.exp(s_prev - m)
    a_cache = jnp.exp(s_cache - m)
    denom = (jnp.sum(a_cur, axis=-1) + jnp.sum(a_prev, axis=-1)
             + jnp.sum(a_cache, axis=-1))[..., None]
    o = jnp.einsum("brij,brjv->briv", a_cur, v_cur)
    o += jnp.einsum("brij,brjv->briv", a_prev, v_prev)
    o += jnp.einsum("bris,brsv->briv", a_cache, cache_u)
    return o / denom


# ---------------------------------------------------------------------------
# Pallas kernel — same math, one (batch, block) grid cell at a time
# ---------------------------------------------------------------------------

def _kernel(q_ref, kc_ref, kp_ref, vc_ref, vp_ref, cb_ref, cu_ref, clb_ref,
            bc_ref, bp_ref, o_ref):
    q = q_ref[0, 0]            # [L, Dk]
    kc = kc_ref[0, 0]          # [L, Dk]
    kp = kp_ref[0, 0]
    vc = vc_ref[0, 0]          # [L, Dv]
    vp = vp_ref[0, 0]
    cb = cb_ref[0]             # [S, Dk]
    cu = cu_ref[0, 0]          # [S, Dv]
    clb = clb_ref[0, 0]        # [S]
    bc = bc_ref[0, 0]          # [L, L]
    bp = bp_ref[0, 0]

    s_cur = jnp.dot(q, kc.T, preferred_element_type=jnp.float32) + bc
    s_prev = jnp.dot(q, kp.T, preferred_element_type=jnp.float32) + bp
    s_cache = jnp.dot(q, cb.T, preferred_element_type=jnp.float32) + clb[None, :]

    m = jnp.maximum(
        jnp.maximum(jnp.max(s_cur, axis=-1), jnp.max(s_prev, axis=-1)),
        jnp.max(s_cache, axis=-1),
    )[:, None]
    a_cur = jnp.exp(s_cur - m)
    a_prev = jnp.exp(s_prev - m)
    a_cache = jnp.exp(s_cache - m)
    denom = (jnp.sum(a_cur, axis=-1) + jnp.sum(a_prev, axis=-1)
             + jnp.sum(a_cache, axis=-1))[:, None]
    o = jnp.dot(a_cur, vc, preferred_element_type=jnp.float32)
    o += jnp.dot(a_prev, vp, preferred_element_type=jnp.float32)
    o += jnp.dot(a_cache, cu, preferred_element_type=jnp.float32)
    o_ref[0, 0] = o / denom


def combine_pallas_fwd_only(q, khat_cur, khat_prev, v_cur, v_prev, codebook,
                            cache_u, cache_lb, bias_cur, bias_prev):
    """Raw pallas_call (forward only). Grid = (Bf, R)."""
    bf, r, l, dk = q.shape
    dv = v_cur.shape[-1]
    s = codebook.shape[1]

    def bx(shape_block, index_map):
        return pl.BlockSpec(shape_block, index_map)

    grid = (bf, r)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            bx((1, 1, l, dk), lambda b, n: (b, n, 0, 0)),   # q
            bx((1, 1, l, dk), lambda b, n: (b, n, 0, 0)),   # khat_cur
            bx((1, 1, l, dk), lambda b, n: (b, n, 0, 0)),   # khat_prev
            bx((1, 1, l, dv), lambda b, n: (b, n, 0, 0)),   # v_cur
            bx((1, 1, l, dv), lambda b, n: (b, n, 0, 0)),   # v_prev
            bx((1, s, dk), lambda b, n: (b, 0, 0)),         # codebook
            bx((1, 1, s, dv), lambda b, n: (b, n, 0, 0)),   # cache_u
            bx((1, 1, s), lambda b, n: (b, n, 0)),          # cache_lb
            bx((1, 1, l, l), lambda b, n: (b, n, 0, 0)),    # bias_cur
            bx((1, 1, l, l), lambda b, n: (b, n, 0, 0)),    # bias_prev
        ],
        out_specs=bx((1, 1, l, dv), lambda b, n: (b, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bf, r, l, dv), q.dtype),
        interpret=True,
    )(q, khat_cur, khat_prev, v_cur, v_prev, codebook, cache_u, cache_lb,
      bias_cur, bias_prev)
    return out


@jax.custom_vjp
def combine_pallas(q, khat_cur, khat_prev, v_cur, v_prev, codebook,
                   cache_u, cache_lb, bias_cur, bias_prev):
    """Pallas forward, jnp-twin backward (interpret mode lacks AD)."""
    return combine_pallas_fwd_only(q, khat_cur, khat_prev, v_cur, v_prev,
                                   codebook, cache_u, cache_lb, bias_cur,
                                   bias_prev)


def _fwd(*args):
    return combine_pallas_fwd_only(*args), args


def _bwd(args, g):
    _, vjp = jax.vjp(combine_jnp, *args)
    return vjp(g)


combine_pallas.defvjp(_fwd, _bwd)


def combine(use_kernel: bool):
    """Select the combine implementation."""
    return combine_pallas if use_kernel else combine_jnp
