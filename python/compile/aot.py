"""AOT compile path: lower jax step functions to HLO text + manifest.

For every (preset, entry-point) pair this emits ``artifacts/<name>.hlo.txt``
and records the flattened input/output structure in
``artifacts/manifest.json``. The rust runtime compiles each HLO once on the
PJRT CPU client and addresses buffers positionally via the manifest.

The interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Initial parameter/codebook values are written as ``<preset>.init.tvq``
(format: tvq.py). Golden step outputs for the rust integration tests are
written as ``golden/<name>.tvq``.

Usage:  python -m compile.aot --out ../artifacts [--quick] [--no-grid]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import VQConfig, PRESETS, throughput_grid, config_json
from . import model, steps, decode, tvq


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def keep_all_inputs(fn: Callable) -> Callable:
    """Guarantee a 1:1 match between manifest inputs and HLO parameters.

    jax.jit DCEs unused arguments out of the lowered module (e.g. the RNG
    seed when all dropout rates are 0), which would desynchronize positional
    buffers on the rust side. We tie a 0-weighted reduction of every input
    leaf into the first f32 output leaf: jaxpr-level DCE then keeps every
    parameter, while XLA folds the zero-multiply away so the runtime cost is
    nil.
    """

    def wrapped(*args):
        out = fn(*args)
        tie = jnp.zeros((), jnp.float32)
        for leaf in jax.tree_util.tree_leaves(args):
            tie = tie + 0.0 * jnp.sum(leaf).astype(jnp.float32)
        def add_tie(x, done=[False]):
            if not done[0] and jnp.issubdtype(x.dtype, jnp.floating):
                done[0] = True
                return x + tie.astype(x.dtype)
            return x
        return jax.tree_util.tree_map(add_tie, out)

    return wrapped


def to_hlo_text(fn: Callable, *example_args) -> str:
    lowered = jax.jit(keep_all_inputs(fn)).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is load-bearing: the default elides big
    # array constants as `constant({...})`, which xla_extension 0.5.1's text
    # parser silently turns into ZEROS (no error). Sinusoid tables, masks and
    # index matrices all ride in constants. See probe.py.
    return comp.as_hlo_text(print_large_constants=True)


def _dtype_str(x) -> str:
    d = np.dtype(x.dtype)
    return {"float32": "f32", "int32": "i32", "uint32": "u32",
            "float64": "f32", "int64": "i32"}[d.name]


def flat_spec(tree, group: str) -> List[Dict]:
    """Manifest leaf descriptors in jax flattening order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        out.append({
            "group": group,
            "path": jax.tree_util.keystr(path),
            "shape": list(np.shape(leaf)),
            "dtype": _dtype_str(leaf),
        })
    return out


def groups_spec(named_trees: List[Tuple[str, object]]) -> List[Dict]:
    spec = []
    for name, tree in named_trees:
        spec.extend(flat_spec(tree, name))
    return spec


# ---------------------------------------------------------------------------
# example-arg construction
# ---------------------------------------------------------------------------

def example_state(cfg: VQConfig, seed: int = 0):
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(seed + 1), cfg)
    carry = model.init_carry(cfg, cfg.batch_size)
    opt = steps.init_opt_state(params)
    return params, opt, cbs, carry


def example_tokens(cfg: VQConfig, extra: int = 1):
    return jnp.zeros((cfg.batch_size, cfg.window_len + extra),
                     dtype=jnp.int32)


# ---------------------------------------------------------------------------
# entry-point registry
# ---------------------------------------------------------------------------

def build_train(cfg: VQConfig):
    params, opt, cbs, carry = example_state(cfg)
    tokens = example_tokens(cfg)
    lr = jnp.zeros((), jnp.float32)
    seed = jnp.zeros((), jnp.int32)

    def fn(params, opt, cbs, carry, tokens, lr, seed):
        return steps.train_step(params, opt, cbs, carry, tokens, lr, seed,
                                cfg)

    args = (params, opt, cbs, carry, tokens, lr, seed)
    outs = jax.eval_shape(fn, *args)
    gin = groups_spec([("params", params), ("opt", opt), ("cb", cbs),
                       ("carry", carry), ("tokens", tokens), ("lr", lr),
                       ("seed", seed)])
    gout = groups_spec([("params", outs[0]), ("opt", outs[1]),
                        ("cb", outs[2]), ("carry", outs[3]),
                        ("metrics", outs[4])])
    return fn, args, gin, gout


def build_eval(cfg: VQConfig):
    params, _, cbs, carry = example_state(cfg)
    tokens = example_tokens(cfg)

    def fn(params, cbs, carry, tokens):
        return steps.eval_step(params, cbs, carry, tokens, cfg)

    args = (params, cbs, carry, tokens)
    outs = jax.eval_shape(fn, *args)
    gin = groups_spec([("params", params), ("cb", cbs), ("carry", carry),
                       ("tokens", tokens)])
    gout = groups_spec([("carry", outs[0]), ("metrics", outs[1])])
    return fn, args, gin, gout


def build_decode(cfg: VQConfig):
    params, _, cbs, _ = example_state(cfg)
    state = decode.init_decode_state(cfg, cfg.batch_size)
    token = jnp.zeros((cfg.batch_size,), jnp.int32)

    def fn(params, cbs, state, token):
        return decode.decode_step(params, cbs, state, token, cfg)

    args = (params, cbs, state, token)
    outs = jax.eval_shape(fn, *args)
    gin = groups_spec([("params", params), ("cb", cbs), ("state", state),
                       ("token", token)])
    gout = groups_spec([("logits", outs[0]), ("state", outs[1])])
    return fn, args, gin, gout


def build_bench(cfg: VQConfig):
    params, _, cbs, carry = example_state(cfg)
    tokens = example_tokens(cfg)

    def fn(params, cbs, carry, tokens):
        return steps.fwdbwd_bench(params, cbs, carry, tokens, cfg)

    args = (params, cbs, carry, tokens)
    outs = jax.eval_shape(fn, *args)
    gin = groups_spec([("params", params), ("cb", cbs), ("carry", carry),
                       ("tokens", tokens)])
    gout = groups_spec([("metrics", outs)])
    return fn, args, gin, gout


ENTRIES = {
    "train": build_train,
    "eval": build_eval,
    "decode": build_decode,
    "bench": build_bench,
}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lower_artifact(name: str, entry: str, cfg: VQConfig, out_dir: str,
                   manifest: Dict) -> None:
    t0 = time.time()
    fn, args, gin, gout = ENTRIES[entry](cfg)
    hlo = to_hlo_text(fn, *args)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(hlo)
    manifest["artifacts"][name] = {
        "entry": entry,
        "hlo": f"{name}.hlo.txt",
        "config": cfg.to_dict(),
        "inputs": gin,
        "outputs": gout,
    }
    print(f"  [{time.time() - t0:5.1f}s] {name}  ({len(hlo) / 1e6:.1f} MB)")


def write_init_state(preset: str, cfg: VQConfig, out_dir: str) -> None:
    params, _, cbs, _ = example_state(cfg)
    tensors = []
    for spec, leaf in zip(
            flat_spec(params, "params"),
            jax.tree_util.tree_leaves(params)):
        tensors.append(("params" + spec["path"], np.asarray(leaf)))
    for spec, leaf in zip(flat_spec(cbs, "cb"),
                          jax.tree_util.tree_leaves(cbs)):
        tensors.append(("cb" + spec["path"], np.asarray(leaf)))
    tvq.write(os.path.join(out_dir, f"{preset}.init.tvq"), tensors)


def write_goldens(preset: str, cfg: VQConfig, out_dir: str) -> None:
    """Run one train + eval + decode step in python; save inputs & outputs
    so the rust runtime tests can assert bit-compatible execution."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    params, opt, cbs, carry = example_state(cfg)
    rng = np.random.RandomState(42)
    tokens = jnp.asarray(rng.randint(
        0, cfg.vocab_size, size=(cfg.batch_size, cfg.window_len + 1)),
        dtype=jnp.int32)
    lr = jnp.asarray(3e-4, jnp.float32)
    seed = jnp.asarray(7, jnp.int32)
    outs = steps.train_step(params, opt, cbs, carry, tokens, lr, seed, cfg)
    tensors = [("tokens", np.asarray(tokens)), ("lr", np.asarray(lr)),
               ("seed", np.asarray(seed)),
               ("metrics", np.asarray(outs[4]))]
    tvq.write(os.path.join(gdir, f"{preset}.train.tvq"), tensors)

    new_carry, metrics = steps.eval_step(params, cbs, carry, tokens, cfg)
    tvq.write(os.path.join(gdir, f"{preset}.eval.tvq"),
              [("tokens", np.asarray(tokens)), ("metrics",
                                                np.asarray(metrics))])

    state = decode.init_decode_state(cfg, cfg.batch_size)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, size=(cfg.batch_size,)),
                      dtype=jnp.int32)
    logits, _ = decode.decode_step(params, cbs, state, tok, cfg)
    tvq.write(os.path.join(gdir, f"{preset}.decode.tvq"),
              [("token", np.asarray(tok)), ("logits", np.asarray(logits))])


PRESET_ENTRIES = {
    "quickstart": ["train", "eval", "decode"],
    "enwik8-tiny": ["train", "eval", "decode"],
    "pg19-tiny": ["train", "eval", "decode"],
    "imagenet64-tiny": ["train", "eval", "decode"],
    "ablate-S32": ["train", "eval"],
    "ablate-S64": ["train", "eval"],
    "ablate-S128": ["train", "eval"],
    "ablate-nocache": ["train", "eval"],
    "ablate-cache": ["train", "eval"],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="quickstart preset only (fast CI loop)")
    ap.add_argument("--state-only", action="store_true",
                    help="rewrite init/golden TVQ files without re-lowering "
                         "HLO (init distributions changed, graphs did not)")
    ap.add_argument("--no-grid", action="store_true",
                    help="skip the throughput benchmark grid")
    ap.add_argument("--grid-max-t", type=int, default=4096)
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    manifest: Dict = {"artifacts": {}}

    presets = (["quickstart"] if args.quick else list(PRESET_ENTRIES))
    print(f"lowering {len(presets)} presets -> {out_dir}")
    for preset in presets:
        cfg = PRESETS[preset]
        if not args.state_only:
            for entry in PRESET_ENTRIES[preset]:
                lower_artifact(f"{preset}.{entry}", entry, cfg, out_dir,
                               manifest)
        write_init_state(preset, cfg, out_dir)
        write_goldens(preset, cfg, out_dir)

    # quadratic-attention quality baseline twin (Table 3 comparison)
    if not args.quick:
        cfg = PRESETS["enwik8-tiny"].replace(attn_type="full")
        if not args.state_only:
            for entry in ("train", "eval"):
                lower_artifact(f"enwik8-tiny-full.{entry}", entry, cfg,
                               out_dir, manifest)
        write_init_state("enwik8-tiny-full", cfg, out_dir)

    if not args.no_grid and not args.quick:
        grid = throughput_grid(
            seq_lens=[t for t in (256, 1024, 4096) if t <= args.grid_max_t])
        print(f"lowering throughput grid ({len(grid)} artifacts)")
        for name, cfg in grid.items():
            if not args.state_only:
                lower_artifact(name, "bench", cfg, out_dir, manifest)
            write_init_state(name, cfg, out_dir)

    if args.state_only:
        print("state-only: manifest untouched")
        return
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
