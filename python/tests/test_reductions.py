"""Cross-block reduction tests (Appendix E Codes 2-4, Appendix B)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import reductions as red, ref


def rand_zv(seed, b, r, l, s, dv):
    kz, kv = jax.random.split(jax.random.PRNGKey(seed))
    z = jax.random.randint(kz, (b, r, l), 0, s)
    v = jax.random.normal(kv, (b, r, l, dv))
    return z, v


METHODS = ["serial", "matmul", "assoc"]


@pytest.mark.parametrize("method", METHODS)
def test_matches_naive(method):
    z, v = rand_zv(0, 2, 5, 8, 6, 4)
    u, c = red.get_cache_vars(z, v, 6, method)
    u_ref, c_ref = ref.naive_cache_vars(z, v, 6)
    np.testing.assert_allclose(np.asarray(c), c_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(u), u_ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m2", ["matmul", "assoc"])
def test_methods_agree(m2):
    z, v = rand_zv(1, 3, 6, 4, 8, 5)
    u1, c1 = red.get_cache_vars(z, v, 8, "serial")
    u2, c2 = red.get_cache_vars(z, v, 8, m2)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), atol=1e-4,
                               rtol=1e-4)


def test_running_mean_is_bounded():
    """Remark 3.9: storing means keeps magnitudes bounded by max |v|."""
    z, v = rand_zv(2, 1, 16, 8, 4, 3)
    u, _ = red.get_cache_vars(z, v, 4, "serial")
    assert float(jnp.max(jnp.abs(u))) <= float(jnp.max(jnp.abs(v))) + 1e-5


def test_counts_accumulate_monotonically():
    z, v = rand_zv(3, 1, 6, 8, 4, 2)
    _, c = red.get_cache_vars(z, v, 4, "assoc")
    c = np.asarray(c)
    assert (np.diff(c.sum(-1), axis=1) >= -1e-6).all()
    # total count through block r == (r+1) * L
    np.testing.assert_allclose(c.sum(-1)[0], (np.arange(6) + 1) * 8)


def test_shift2_alignment():
    z, v = rand_zv(4, 1, 5, 4, 4, 2)
    u, c = red.get_cache_vars(z, v, 4, "serial")
    us, cs = red.shift2(u, c)
    assert float(jnp.sum(cs[:, :2])) == 0.0
    np.testing.assert_allclose(np.asarray(cs[:, 2:]), np.asarray(c[:, :-2]))


def test_merge_cache_monoid():
    """merge(merge(a,b),c) == merge(a, merge(b,c)) — required for the
    associative scan and the TBPTT carry."""
    keys = jax.random.split(jax.random.PRNGKey(5), 6)
    s, dv = 6, 3
    mk_u = lambda k: jax.random.normal(k, (2, s, dv))
    mk_l = lambda k: jax.random.randint(k, (2, s), 0, 5).astype(jnp.float32)
    ua, la = mk_u(keys[0]), mk_l(keys[1])
    ub, lb = mk_u(keys[2]), mk_l(keys[3])
    uc, lc = mk_u(keys[4]), mk_l(keys[5])
    u1, l1 = red.merge_cache(*red.merge_cache(ua, la, ub, lb), uc, lc)
    u2, l2 = red.merge_cache(ua, la, *red.merge_cache(ub, lb, uc, lc))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    # means only comparable where counts > 0
    mask = np.asarray(l1) > 0
    np.testing.assert_allclose(np.asarray(u1)[mask], np.asarray(u2)[mask],
                               atol=1e-4, rtol=1e-4)


def test_merge_cache_identity():
    u = jax.random.normal(jax.random.PRNGKey(6), (2, 4, 3))
    l = jnp.ones((2, 4)) * 3
    zu, zl = jnp.zeros_like(u), jnp.zeros_like(l)
    mu, ml = red.merge_cache(u, l, zu, zl)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(u), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ml), np.asarray(l), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 6),
       st.integers(1, 8), st.integers(2, 10))
def test_hypothesis_all_methods_match_naive(seed, b, r, l, s):
    z, v = rand_zv(seed, b, r, l, s, 3)
    u_ref, c_ref = ref.naive_cache_vars(z, v, s)
    for m in METHODS:
        u, c = red.get_cache_vars(z, v, s, m)
        np.testing.assert_allclose(np.asarray(c), c_ref, atol=1e-4,
                                   err_msg=m)
        np.testing.assert_allclose(np.asarray(u), u_ref, atol=1e-3,
                                   rtol=1e-3, err_msg=m)
