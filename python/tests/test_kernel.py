"""L1 Pallas kernel vs the pure-jnp oracle — the CORE correctness signal.

The kernel (kernels/vq_attn.py) must agree with (a) the jnp twin that its
custom backward pass differentiates, and (b) the quadratic oracle over
quantized keys. Hypothesis sweeps shapes.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, vq
from compile.kernels.vq_attn import combine_jnp, combine_pallas, NEG_INF
from tests.helpers import rand_inputs, combine_inputs_from_seq, assert_close


def build_combine_inputs(seed, b, r, l, s, dk, dv):
    q, k, v, codebook, bias_all = rand_inputs(seed, b, r, l, s, dk, dv)
    k_hat, z, _ = vq.stvq(k[:, :, None, :], codebook)
    k_hat, z = k_hat[:, :, 0], z[:, :, 0]
    parts = combine_inputs_from_seq(q, k_hat, z, v, bias_all, l, s)
    cb_f = jnp.broadcast_to(codebook[0][None], (b, s, dk))
    return parts, cb_f, (q, k_hat, v, bias_all)


SHAPES = [
    (1, 2, 4, 8, 8, 16),
    (2, 3, 8, 16, 8, 8),
    (1, 4, 16, 32, 16, 32),
]


@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_jnp_twin(shape):
    (qb, kb, kp, vb, vp, cu, clb, bc, bp), cb_f, _ = build_combine_inputs(
        0, *shape)
    got = combine_pallas(qb, kb, kp, vb, vp, cb_f, cu, clb, bc, bp)
    want = combine_jnp(qb, kb, kp, vb, vp, cb_f, cu, clb, bc, bp)
    assert_close(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_quadratic_oracle(shape):
    b, r, l, s, dk, dv = shape
    parts, cb_f, (q, k_hat, v, bias_all) = build_combine_inputs(1, *shape)
    got = combine_pallas(parts[0], parts[1], parts[2], parts[3], parts[4],
                         cb_f, parts[5], parts[6], parts[7], parts[8])
    want = ref.vq_attention_quadratic(q, k_hat, v, bias_all, l)
    assert_close(got.reshape(b, r * l, dv), want, atol=5e-5, rtol=5e-4)


def test_kernel_gradients_flow():
    """The custom_vjp must differentiate through all float inputs."""
    (qb, kb, kp, vb, vp, cu, clb, bc, bp), cb_f, _ = build_combine_inputs(
        2, 1, 2, 4, 8, 8, 8)

    def loss_k(q, v):
        return jnp.sum(
            combine_pallas(q, kb, kp, v, vp, cb_f, cu, clb, bc, bp) ** 2)

    def loss_j(q, v):
        return jnp.sum(
            combine_jnp(q, kb, kp, v, vp, cb_f, cu, clb, bc, bp) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1))(qb, vb)
    gj = jax.grad(loss_j, argnums=(0, 1))(qb, vb)
    for a, b_ in zip(gk, gj):
        assert_close(a, b_, atol=1e-5, rtol=1e-4)
    assert float(jnp.max(jnp.abs(gk[0]))) > 0


def test_kernel_under_jit():
    (qb, kb, kp, vb, vp, cu, clb, bc, bp), cb_f, _ = build_combine_inputs(
        3, 1, 2, 8, 8, 4, 4)
    f = jax.jit(combine_pallas)
    got = f(qb, kb, kp, vb, vp, cb_f, cu, clb, bc, bp)
    want = combine_jnp(qb, kb, kp, vb, vp, cb_f, cu, clb, bc, bp)
    assert_close(got, want, atol=1e-5, rtol=1e-5)


def test_kernel_attends_cache():
    """Attention output must move when the cached value means change."""
    (qb, kb, kp, vb, vp, cu, clb, bc, bp), cb_f, _ = build_combine_inputs(
        4, 1, 4, 4, 8, 8, 8)
    base = combine_pallas(qb, kb, kp, vb, vp, cb_f, cu, clb, bc, bp)
    moved = combine_pallas(qb, kb, kp, vb, vp, cb_f, cu + 1.0, clb, bc, bp)
    # later blocks (with non-empty cache) must change
    diff = float(jnp.max(jnp.abs(base[:, 2:] - moved[:, 2:])))
    assert diff > 1e-4


def test_kernel_ignores_empty_cache():
    """With all log-count biases at -inf, the cache contributes nothing."""
    (qb, kb, kp, vb, vp, cu, clb, bc, bp), cb_f, _ = build_combine_inputs(
        5, 1, 3, 4, 8, 8, 8)
    clb_off = jnp.full_like(clb, NEG_INF)
    a = combine_pallas(qb, kb, kp, vb, vp, cb_f, cu, clb_off, bc, bp)
    b_ = combine_pallas(qb, kb, kp, vb, vp, cb_f, cu * 0 + 99.0, clb_off,
                        bc, bp)
    assert_close(a, b_, atol=1e-6, rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(0, 100_000),
    st.integers(1, 2),
    st.integers(1, 4),
    st.sampled_from([2, 4, 8]),
    st.sampled_from([4, 8, 16]),
    st.sampled_from([4, 8]),
    st.sampled_from([4, 8, 12]),
)
def test_hypothesis_kernel_vs_oracle(seed, b, r, l, s, dk, dv):
    parts, cb_f, (q, k_hat, v, bias_all) = build_combine_inputs(
        seed, b, r, l, s, dk, dv)
    got = combine_pallas(parts[0], parts[1], parts[2], parts[3], parts[4],
                         cb_f, parts[5], parts[6], parts[7], parts[8])
    want = ref.vq_attention_quadratic(q, k_hat, v, bias_all, l)
    np.testing.assert_allclose(
        np.asarray(got.reshape(b, r * l, dv)), np.asarray(want),
        atol=1e-4, rtol=1e-3)
