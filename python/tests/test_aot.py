"""AOT pipeline tests: manifest coherence, input-retention wrapper, HLO
text properties required by the old-runtime parser (DESIGN.md §10)."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model, steps, decode
from compile.configs import PRESETS

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_keep_all_inputs_retains_unused_args():
    def fn(x, unused):
        return (x * 2.0,)

    wrapped = aot.keep_all_inputs(fn)
    lowered = jax.jit(wrapped).lower(jnp.ones((3,)), jnp.ones((5,)))
    text = lowered.compiler_ir("stablehlo")
    # both parameters must survive lowering
    n_args = str(text).count("%arg")
    assert "%arg1" in str(text), "unused arg was DCE'd"
    # and values are unchanged
    out = jax.jit(wrapped)(jnp.asarray([1.0, 2.0, 3.0]), jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(out[0]), [2.0, 4.0, 6.0])
    del n_args


def test_hlo_text_has_no_elided_constants():
    """print_large_constants=True is load-bearing (parser zeroes `{...}`)."""
    big = jnp.asarray(np.random.RandomState(0).randn(64, 32).astype("f"))

    def fn(x):
        return (x @ big,)

    text = aot.to_hlo_text(fn, jnp.ones((4, 64)))
    assert "constant({...})" not in text
    assert "f32[64,32]" in text


def test_entry_builders_cover_groups():
    cfg = PRESETS["quickstart"]
    fn, args, gin, gout = aot.build_train(cfg)
    in_groups = {l["group"] for l in gin}
    assert in_groups == {"params", "opt", "cb", "carry", "tokens", "lr",
                         "seed"}
    out_groups = {l["group"] for l in gout}
    assert out_groups == {"params", "opt", "cb", "carry", "metrics"}
    # leaf counts of recurring groups must match between inputs and outputs
    for g in ("params", "opt", "cb", "carry"):
        n_in = sum(1 for l in gin if l["group"] == g)
        n_out = sum(1 for l in gout if l["group"] == g)
        assert n_in == n_out, g


def test_group_spec_matches_tree_leaves():
    cfg = PRESETS["quickstart"]
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    spec = aot.flat_spec(params, "params")
    leaves = jax.tree_util.tree_leaves(params)
    assert len(spec) == len(leaves)
    for s, leaf in zip(spec, leaves):
        assert tuple(s["shape"]) == np.shape(leaf)


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS,
                                                    "manifest.json")),
                    reason="run `make artifacts` first")
class TestBuiltManifest:
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self):
        m = self.manifest()
        for name, spec in m["artifacts"].items():
            path = os.path.join(ARTIFACTS, spec["hlo"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 1000, name

    def test_preset_artifacts_present(self):
        m = self.manifest()
        for preset, entries in aot.PRESET_ENTRIES.items():
            for e in entries:
                assert f"{preset}.{e}" in m["artifacts"], f"{preset}.{e}"

    def test_input_shapes_match_configs(self):
        m = self.manifest()
        spec = m["artifacts"]["quickstart.train"]
        cfg = PRESETS["quickstart"]
        tokens = [l for l in spec["inputs"] if l["group"] == "tokens"]
        assert tokens[0]["shape"] == [cfg.batch_size, cfg.window_len + 1]
        assert spec["config"]["n_code"] == cfg.n_code

    def test_init_state_matches_manifest_param_specs(self):
        from compile import tvq
        m = self.manifest()
        spec = m["artifacts"]["quickstart.train"]
        init = tvq.read(os.path.join(ARTIFACTS, "quickstart.init.tvq"))
        by_group = {}
        for name, arr in init:
            g = name.split("[")[0].split("/")[0]
            by_group.setdefault(g, []).append(arr)
        params_spec = [l for l in spec["inputs"] if l["group"] == "params"]
        assert len(by_group["params"]) == len(params_spec)
        for arr, leaf in zip(by_group["params"], params_spec):
            assert list(arr.shape) == leaf["shape"]
