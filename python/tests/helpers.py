"""Shared fixtures/utilities for the python test-suite."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from compile.configs import VQConfig
from compile import model
from compile.kernels import vq, reductions as red
from compile.kernels.vq_attn import NEG_INF


def rand_inputs(seed, b, r, l, s, dk, dv):
    """Random, pre-aligned inputs for the attention combine (first window:
    no carried prev block, empty initial cache)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    t = r * l
    q = jax.random.normal(ks[0], (b, t, dk)) / np.sqrt(dk)
    k = jax.random.normal(ks[1], (b, t, dk)) / np.sqrt(dk)
    v = jax.random.normal(ks[2], (b, t, dv))
    codebook = jax.random.normal(ks[3], (1, s, dk)) / np.sqrt(dk)
    # q-dependent per-distance biases
    wr = jax.random.normal(ks[4], (dk, 2 * l)) * 0.1
    bias_all = q @ wr  # [b, t, 2l]
    return q, k, v, codebook, bias_all


def combine_inputs_from_seq(q, k_hat, z, v, bias_all, l, s, reduction="serial"):
    """Build the block-aligned inputs the combine expects, from full-sequence
    tensors (single kv head, first window)."""
    b, t, dk = q.shape
    dv = v.shape[-1]
    r = t // l
    qb = q.reshape(b, r, l, dk)
    kb = k_hat.reshape(b, r, l, dk)
    vb = v.reshape(b, r, l, dv)
    zb = z.reshape(b, r, l)

    u_cum, l_cum = red.REDUCTIONS[reduction](*red.block_summaries(zb, vb, s))
    cache_u, cache_l = red.shift2(u_cum, l_cum)
    cache_lb = jnp.where(cache_l > 0, jnp.log(jnp.clip(cache_l, min=1.0)),
                         NEG_INF)

    kprev = jnp.pad(kb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))

    ba = bias_all.reshape(b, r, l, 2 * l)
    from compile.layers import gather_band_biases
    bias_cur, bias_prev = gather_band_biases(ba, l)
    # first block has no previous block
    inval = jnp.zeros((b, r, 1, 1)).at[:, 0].set(NEG_INF)
    bias_prev = bias_prev + inval
    return qb, kb, kprev, vb, vprev, cache_u, cache_lb, bias_cur, bias_prev


def assert_close(a, b, atol=2e-4, rtol=2e-4, msg=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol, err_msg=msg)
