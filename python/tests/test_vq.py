"""Vector quantizer + EMA codebook tests (Definitions 2.1/2.6, §3.4.1)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import vq, ref


def mk(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


class TestNearestCode:
    def test_matches_naive(self):
        k = mk(0, 40, 1, 8)
        cb = mk(1, 1, 16, 8)
        z = vq.nearest_code(k, cb)
        z_ref = ref.naive_quantize(np.asarray(k[:, 0]), np.asarray(cb[0]))
        np.testing.assert_array_equal(np.asarray(z[:, 0]), z_ref)

    def test_codeword_maps_to_itself(self):
        cb = mk(2, 1, 8, 4)
        z = vq.nearest_code(cb[0][:, None, :], cb)
        np.testing.assert_array_equal(np.asarray(z[:, 0]), np.arange(8))

    def test_multihead_independent(self):
        k = mk(3, 10, 2, 4)
        cb = mk(4, 2, 8, 4)
        z = vq.nearest_code(k, cb)
        for h in range(2):
            zh = vq.nearest_code(k[:, h:h+1], cb[h:h+1])
            np.testing.assert_array_equal(np.asarray(z[:, h]),
                                          np.asarray(zh[:, 0]))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 1000), st.integers(2, 24), st.integers(1, 12))
    def test_hypothesis_nearest_is_argmin(self, seed, s, d):
        k = jax.random.normal(jax.random.PRNGKey(seed), (5, 1, d))
        cb = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, s, d))
        z = np.asarray(vq.nearest_code(k, cb))[:, 0]
        dists = ((np.asarray(k)[:, 0, None, :] -
                  np.asarray(cb)[0][None, :, :]) ** 2).sum(-1)
        np.testing.assert_array_equal(z, dists.argmin(-1))


class TestSTVQ:
    def test_output_is_codeword(self):
        k = mk(5, 20, 1, 8)
        cb_state = vq.codebook_init(jax.random.PRNGKey(6), 1, 16, 8)
        k_hat, z, _ = vq.stvq(k, cb_state["codebook"])
        gathered = np.asarray(cb_state["codebook"])[0][np.asarray(z)[:, 0]]
        np.testing.assert_allclose(np.asarray(k_hat)[:, 0], gathered,
                                   rtol=1e-5, atol=1e-6)

    def test_straight_through_gradient_is_identity(self):
        """Remark 2.7: d stvq(k)/dk == I via the STE."""
        cb = mk(7, 1, 8, 4)

        def f(k):
            k_hat, _, _ = vq.stvq(k[None, None, :], cb)
            return jnp.sum(k_hat * jnp.arange(4.0))

        g = jax.grad(f)(jnp.ones((4,)))
        np.testing.assert_allclose(np.asarray(g), np.arange(4.0), rtol=1e-6)

    def test_commit_loss_value(self):
        k = mk(8, 30, 1, 8)
        cb = mk(9, 1, 16, 8)
        k_hat, z, commit = vq.stvq(k, cb)
        want = np.mean(np.sum((np.asarray(k) - np.asarray(k_hat)) ** 2, -1))
        np.testing.assert_allclose(float(commit), want, rtol=1e-5)

    def test_commit_gradient_points_to_codeword(self):
        cb = jnp.zeros((1, 4, 2)).at[0, 0].set(jnp.asarray([1.0, 0.0]))

        def f(k):
            _, _, commit = vq.stvq(k[None, None, :], cb)
            return commit

        k0 = jnp.asarray([0.9, 0.1])
        g = jax.grad(f)(k0)
        # d/dk ||k - c||^2 = 2(k - c)
        np.testing.assert_allclose(np.asarray(g),
                                   2 * (np.asarray(k0) - np.array([1.0, 0.0])),
                                   rtol=1e-5)


class TestEmaUpdate:
    def test_counts_move_toward_assignments(self):
        state = vq.codebook_init(jax.random.PRNGKey(10), 1, 4, 2)
        k = jnp.tile(jnp.asarray([[5.0, 5.0]]), (64, 1))[:, None, :]
        z = vq.nearest_code(k, state["codebook"])
        s1 = vq.ema_update(state, k, z, gamma=0.5)
        zi = int(np.asarray(z)[0, 0])
        assert float(s1["ema_count"][0, zi]) > float(state["ema_count"][0, zi])

    def test_codebook_converges_to_cluster_mean(self):
        state = vq.codebook_init(jax.random.PRNGKey(11), 1, 2, 2)
        target = jnp.asarray([3.0, -2.0])
        for _ in range(200):
            k = target[None, None, :] + 0.01 * mk(12, 32, 1, 2)
            z = vq.nearest_code(k, state["codebook"])
            state = vq.ema_update(state, k, z, gamma=0.9)
        cb = np.asarray(state["codebook"])[0]
        best = np.abs(cb - np.asarray(target)).sum(-1).min()
        assert best < 0.1, cb

    def test_no_nan_with_dead_codes(self):
        state = vq.codebook_init(jax.random.PRNGKey(13), 1, 8, 2)
        k = jnp.zeros((16, 1, 2))
        z = vq.nearest_code(k, state["codebook"])
        for _ in range(500):
            state = vq.ema_update(state, k, z, gamma=0.99)
        assert np.isfinite(np.asarray(state["codebook"])).all()

    def test_gamma_one_freezes(self):
        state = vq.codebook_init(jax.random.PRNGKey(14), 1, 4, 2)
        k = mk(15, 8, 1, 2)
        z = vq.nearest_code(k, state["codebook"])
        s1 = vq.ema_update(state, k, z, gamma=1.0)
        np.testing.assert_allclose(np.asarray(s1["ema_count"]),
                                   np.asarray(state["ema_count"]), rtol=1e-6)


class TestPerplexity:
    def test_uniform_is_full(self):
        z = jnp.arange(16, dtype=jnp.int32)
        assert abs(float(vq.codebook_perplexity(z, 16)) - 16.0) < 1e-3

    def test_collapse_is_one(self):
        z = jnp.zeros((64,), dtype=jnp.int32)
        assert abs(float(vq.codebook_perplexity(z, 16)) - 1.0) < 1e-3
