"""Model-level tests: shapes, head types, reductions equivalence,
input-scanning variant, full-attention baseline, ablation configs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.configs import VQConfig, PRESETS, throughput_grid
from compile import model
from tests.helpers import assert_close

BASE = VQConfig(vocab_size=64, d_model=32, d_k=8, d_v=64, n_layers=2,
                n_code=16, block_len=8, window_len=32, batch_size=2)


def setup(cfg, seed=0):
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(seed + 1), cfg)
    carry = model.init_carry(cfg, cfg.batch_size)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 2),
                                (cfg.batch_size, cfg.window_len), 0,
                                cfg.vocab_size)
    return params, cbs, carry, tokens


def fwd(cfg, seed=0, train=False):
    params, cbs, carry, tokens = setup(cfg, seed)
    return model.forward_window(params, cbs, carry, tokens, cfg,
                                jax.random.PRNGKey(9), train)


@pytest.mark.parametrize("head,heads", [("shga", 1), ("mha", 4), ("mqa", 4)])
def test_head_types_shapes(head, heads):
    cfg = BASE.replace(head_type=head, n_heads=heads)
    logits, carry, aux = fwd(cfg)
    assert logits.shape == (2, 32, 64)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(aux["ema"]) == cfg.n_layers


@pytest.mark.parametrize("head,heads", [("shga", 1), ("mha", 4), ("mqa", 4)])
def test_full_attention_heads(head, heads):
    cfg = BASE.replace(attn_type="full", head_type=head, n_heads=heads)
    logits, carry, aux = fwd(cfg)
    assert logits.shape == (2, 32, 64)
    assert np.isfinite(np.asarray(logits)).all()
    assert aux["ema"] == []


def test_reductions_all_equal():
    outs = {}
    for m in ("serial", "matmul", "assoc"):
        outs[m] = fwd(BASE.replace(reduction=m))[0]
    assert_close(outs["serial"], outs["matmul"], atol=2e-4, rtol=2e-3)
    assert_close(outs["serial"], outs["assoc"], atol=2e-4, rtol=2e-3)


def test_inputscan_equals_batched():
    a = fwd(BASE.replace(reduction="serial"))[0]
    b = fwd(BASE.replace(reduction="inputscan"))[0]
    assert_close(a, b, atol=3e-4, rtol=3e-3)


def test_kernel_equals_jnp_forward():
    a = fwd(BASE.replace(use_kernel=False))[0]
    b = fwd(BASE.replace(use_kernel=True))[0]
    assert_close(a, b, atol=1e-5, rtol=1e-5)


def test_cache_ablation_changes_output():
    """use_cache=False must change predictions once context exceeds 2L."""
    with_c = fwd(BASE)[0]
    without = fwd(BASE.replace(use_cache=False))[0]
    # first two blocks identical (no cache yet), later blocks differ
    assert_close(with_c[:, :16], without[:, :16], atol=1e-5, rtol=1e-4)
    assert float(jnp.max(jnp.abs(with_c[:, 16:] - without[:, 16:]))) > 1e-4


def test_abs_pe_changes_with_position():
    cfg = BASE.replace(use_abs_pe=True)
    params, cbs, carry, tokens = setup(cfg)
    l0, _, _ = model.forward_window(params, cbs, carry, tokens, cfg,
                                    jax.random.PRNGKey(0), False)
    carry2 = dict(carry)
    carry2["pos"] = carry["pos"] + 100
    l1, _, _ = model.forward_window(params, cbs, carry2, tokens, cfg,
                                    jax.random.PRNGKey(0), False)
    assert float(jnp.max(jnp.abs(l0 - l1))) > 1e-4


def test_carry_pos_and_flag_advance():
    cfg = BASE
    _, carry, _ = fwd(cfg)
    assert int(carry["pos"][0]) == cfg.window_len
    assert float(carry["has_prev"][0]) == 1.0


def test_dropout_only_in_train_mode():
    cfg = BASE.replace(dropout_rate=0.5)
    params, cbs, carry, tokens = setup(cfg)
    e1, _, _ = model.forward_window(params, cbs, carry, tokens, cfg,
                                    jax.random.PRNGKey(1), False)
    e2, _, _ = model.forward_window(params, cbs, carry, tokens, cfg,
                                    jax.random.PRNGKey(2), False)
    assert_close(e1, e2, atol=0, rtol=0)  # eval is deterministic
    t1, _, _ = model.forward_window(params, cbs, carry, tokens, cfg,
                                    jax.random.PRNGKey(1), True)
    t2, _, _ = model.forward_window(params, cbs, carry, tokens, cfg,
                                    jax.random.PRNGKey(2), True)
    assert float(jnp.max(jnp.abs(t1 - t2))) > 1e-5


def test_tied_embeddings():
    cfg = BASE.replace(tie_embeddings=True)
    params, cbs, carry, tokens = setup(cfg)
    assert "head" not in params
    logits, _, _ = model.forward_window(params, cbs, carry, tokens, cfg,
                                        jax.random.PRNGKey(0), False)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_count_scales_with_width():
    small = model.param_count(model.init_params(jax.random.PRNGKey(0), BASE))
    big = model.param_count(model.init_params(
        jax.random.PRNGKey(0), BASE.replace(d_model=64, d_v=128)))
    assert big > 2 * small


def test_presets_all_construct():
    for name, cfg in PRESETS.items():
        assert cfg.window_len % cfg.block_len == 0, name
        assert cfg.d_v % cfg.n_heads == 0, name


def test_throughput_grid_names_and_variants():
    grid = throughput_grid(seq_lens=[256], head_types=["shga"],
                           variants=["full", "vq-serial"])
    assert set(grid) == {"tput-shga-full-T256", "tput-shga-vq-serial-T256"}
    assert grid["tput-shga-full-T256"].attn_type == "full"
    assert grid["tput-shga-vq-serial-T256"].reduction == "serial"
