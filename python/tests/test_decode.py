"""Decode-path tests: the token-level cache roll must reproduce windowed
training attention exactly (§4.1 'cache update logic can be applied every
token'), across head types and long horizons crossing many block
boundaries."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.configs import VQConfig
from compile import model, decode
from tests.helpers import assert_close

BASE = VQConfig(vocab_size=64, d_model=32, d_k=8, d_v=64, n_layers=2,
                n_code=16, block_len=8, window_len=32, batch_size=2)


def run_both(cfg, n_windows=2, seed=0):
    b = cfg.batch_size
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(seed + 1), cfg)
    w = cfg.window_len
    toks = jax.random.randint(jax.random.PRNGKey(seed + 2),
                              (b, n_windows * w), 0, cfg.vocab_size)
    carry = model.init_carry(cfg, b)
    logits = []
    for n in range(n_windows):
        lg, carry, _ = model.forward_window(
            params, cbs, carry, toks[:, n * w:(n + 1) * w], cfg,
            jax.random.PRNGKey(7), False)
        logits.append(lg)
    win_logits = jnp.concatenate(logits, axis=1)

    st = decode.init_decode_state(cfg, b)
    outs = []
    for t in range(n_windows * w):
        lg, st = decode.decode_step(params, cbs, st, toks[:, t], cfg)
        outs.append(lg)
    return win_logits, jnp.stack(outs, axis=1), st


def test_decode_matches_training_forward():
    win, dec, _ = run_both(BASE, n_windows=2)
    assert_close(dec, win, atol=3e-4, rtol=3e-3)


@pytest.mark.parametrize("head,heads", [("mha", 2), ("mqa", 2)])
def test_decode_matches_multihead(head, heads):
    cfg = BASE.replace(head_type=head, n_heads=heads)
    win, dec, _ = run_both(cfg, n_windows=1)
    assert_close(dec, win, atol=3e-4, rtol=3e-3)


def test_decode_long_horizon_many_boundaries():
    """8 blocks: cache folds happen repeatedly and must stay consistent."""
    cfg = BASE.replace(window_len=16, block_len=4)
    win, dec, st = run_both(cfg, n_windows=4)
    assert_close(dec, win, atol=5e-4, rtol=5e-3)
    # after 64 tokens with L=4: cache holds blocks 0..14 (60 tokens... the
    # last two blocks stay in the window), counts = 56
    counts = float(jnp.sum(st["layers"][0]["cache_l"][0, 0]))
    assert counts == 64 - 2 * 4, counts


def test_decode_with_abs_pe():
    cfg = BASE.replace(use_abs_pe=True)
    win, dec, _ = run_both(cfg, n_windows=1)
    assert_close(dec, win, atol=3e-4, rtol=3e-3)


def test_decode_no_cache_ablation():
    cfg = BASE.replace(use_cache=False)
    win, dec, _ = run_both(cfg, n_windows=2)
    assert_close(dec, win, atol=3e-4, rtol=3e-3)


def test_decode_state_isolated_across_batch():
    """Slot b's logits depend only on slot b's tokens (continuous batching
    safety: the rust engine relies on strict per-row isolation)."""
    cfg = BASE
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(1), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 64)
    t2 = t1.at[1].set((t1[1] + 17) % 64)  # change only row 1

    def run(toks):
        st = decode.init_decode_state(cfg, 2)
        out = []
        for t in range(toks.shape[1]):
            lg, st = decode.decode_step(params, cbs, st, toks[:, t], cfg)
            out.append(lg)
        return jnp.stack(out, 1)

    a, b = run(t1), run(t2)
    assert_close(a[0], b[0], atol=0, rtol=0)      # row 0 identical
    assert float(jnp.max(jnp.abs(a[1] - b[1]))) > 1e-4  # row 1 differs


def test_decode_pos_increments():
    cfg = BASE
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(1), cfg)
    st = decode.init_decode_state(cfg, 2)
    _, st = decode.decode_step(params, cbs, st, jnp.zeros((2,), jnp.int32),
                               cfg)
    assert int(st["pos"][0]) == 1
