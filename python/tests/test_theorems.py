"""Exactness of the linear-time recurrence (Theorems 3.4-3.7).

The central claim of the paper: given vector-quantized keys, blockwise
attention against (codebook scores + cache vars) is *exactly* softmax dense
attention over the full sequence. We verify this against the quadratic
oracle for a sweep of shapes and all three reduction methods.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.kernels import ref, vq, reductions as red
from compile.kernels.vq_attn import combine_jnp
from tests.helpers import rand_inputs, combine_inputs_from_seq, assert_close


SHAPES = [
    # (b, r, l, s, dk, dv)
    (1, 2, 4, 8, 8, 16),
    (2, 4, 8, 16, 8, 8),
    (1, 8, 4, 4, 4, 4),
    (2, 3, 16, 32, 16, 32),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("reduction", ["serial", "matmul", "assoc"])
def test_linear_equals_quadratic(shape, reduction):
    """Theorem 3.7: block recurrence == dense softmax over quantized keys."""
    b, r, l, s, dk, dv = shape
    q, k, v, codebook, bias_all = rand_inputs(0, b, r, l, s, dk, dv)
    k_hat, z, _ = vq.stvq(k[:, :, None, :], codebook)
    k_hat, z = k_hat[:, :, 0], z[:, :, 0]

    want = ref.vq_attention_quadratic(q, k_hat, v, bias_all, l)

    qb, kb, kp, vb, vp, cu, clb, bc, bp = combine_inputs_from_seq(
        q, k_hat, z, v, bias_all, l, s, reduction)
    cb_f = jnp.broadcast_to(codebook[0][None], (b, s, dk))
    got = combine_jnp(qb, kb, kp, vb, vp, cb_f, cu, clb, bc, bp)
    got = got.reshape(b, r * l, dv)
    assert_close(got, want, atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_factorization_elementwise(shape):
    """Theorem 3.4: phi(Q Khat^T) == phi(Q C^T) Delta for element-wise phi."""
    b, r, l, s, dk, dv = shape
    t = r * l
    q, k, v, codebook, _ = rand_inputs(1, b, r, l, s, dk, dv)
    k_hat, z, _ = vq.stvq(k[:, :, None, :], codebook)
    k_hat, z = k_hat[:, :, 0], z[:, :, 0]
    phi = jnp.exp
    lhs = phi(jnp.einsum("bid,bjd->bij", q, k_hat))
    delta = jax.nn.one_hot(z, s).transpose(0, 2, 1)     # [b, s, t]
    rhs = jnp.einsum("bis,bst->bit", phi(jnp.einsum(
        "bid,sd->bis", q, codebook[0])), delta)
    assert_close(lhs, rhs, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_factorization_softmax(shape):
    """Theorem 3.5: softmax(Q Khat^T) == normalized exp(Q C^T) Delta."""
    b, r, l, s, dk, dv = shape
    q, k, v, codebook, _ = rand_inputs(2, b, r, l, s, dk, dv)
    k_hat, z, _ = vq.stvq(k[:, :, None, :], codebook)
    k_hat, z = k_hat[:, :, 0], z[:, :, 0]
    lhs = jax.nn.softmax(jnp.einsum("bid,bjd->bij", q, k_hat), axis=-1)
    delta = jax.nn.one_hot(z, s).transpose(0, 2, 1)
    e = jnp.einsum("bis,bst->bit",
                   jnp.exp(jnp.einsum("bid,sd->bis", q, codebook[0])), delta)
    rhs = e / jnp.sum(e, axis=-1, keepdims=True)
    assert_close(lhs, rhs, atol=1e-5, rtol=1e-4)


def test_guo_inner_product_bound():
    """Theorem 2.2 empirically: E||q^T k - q^T phi(k)||^2 proportional to
    E||k - phi(k)||^2 under isotropic q."""
    key = jax.random.PRNGKey(3)
    d, n, s = 16, 4096, 8
    kq, kk, kc = jax.random.split(key, 3)
    q = jax.random.normal(kq, (n, d))
    k = jax.random.normal(kk, (n, d)) * 2.0
    cb = jax.random.normal(kc, (1, s, d))
    k_hat, _, _ = vq.stvq(k[:, None, :], cb)
    k_hat = k_hat[:, 0]
    lhs = np.mean(np.square(np.einsum("nd,nd->n", q, k - k_hat)))
    rhs = np.mean(np.sum(np.square(k - k_hat), axis=-1))
    # sigma^2 = 1 for standard normal q => lhs ~= rhs
    assert abs(lhs / rhs - 1.0) < 0.15


def test_cache_equals_attending_each_position():
    """The cache term exp(q C^T + log L) @ U == sum over individual cached
    positions of exp(q k_hat_j) v_j (Remark 3.9's running-mean form)."""
    b, t, s, dk, dv = 1, 32, 8, 8, 4
    q1 = jax.random.normal(jax.random.PRNGKey(4), (dk,))
    k = jax.random.normal(jax.random.PRNGKey(5), (t, dk))
    v = jax.random.normal(jax.random.PRNGKey(6), (t, dv))
    cb = jax.random.normal(jax.random.PRNGKey(7), (1, s, dk))
    k_hat, z, _ = vq.stvq(k[:, None, :], cb)
    k_hat, z = k_hat[:, 0], z[:, 0]
    # naive: per-position
    want = sum(np.exp(float(q1 @ k_hat[j])) * np.asarray(v[j])
               for j in range(t))
    # cache form
    onehot = jax.nn.one_hot(z, s)
    counts = onehot.sum(0)
    u = (onehot.T @ v) / np.clip(counts[:, None], 1.0, None)
    scores = np.exp(np.asarray(cb[0] @ q1) + np.log(np.clip(counts, 1e-30,
                                                            None)))
    got = scores @ np.asarray(u)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("reduction", ["serial", "matmul", "assoc"])
def test_carry_across_windows_equals_one_window(reduction):
    """Splitting a sequence into two carried windows must equal processing it
    as one window (the §3.4.2 TBPTT equivalence, forward pass)."""
    from compile.configs import PRESETS
    from compile import model
    cfg = PRESETS["quickstart"].replace(
        use_kernel=False, reduction=reduction, batch_size=2)
    w = cfg.window_len
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 2 * w), 0, 256)
    rng = jax.random.PRNGKey(9)

    cfg2 = cfg.replace(window_len=2 * w)
    carry = model.init_carry(cfg2, 2)
    big, _, _ = model.forward_window(params, cbs, carry, toks, cfg2, rng,
                                     False)
    carry = model.init_carry(cfg, 2)
    l1, c1, _ = model.forward_window(params, cbs, carry, toks[:, :w], cfg,
                                     rng, False)
    l2, _, _ = model.forward_window(params, cbs, c1, toks[:, w:], cfg, rng,
                                    False)
    assert_close(jnp.concatenate([l1, l2], 1), big, atol=3e-4, rtol=3e-3)
