"""Step-function tests: AdamW math, train/eval steps, TVQ store."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.configs import VQConfig
from compile import model, steps, tvq

CFG = VQConfig(vocab_size=64, d_model=32, d_k=8, d_v=64, n_layers=2,
               n_code=16, block_len=8, window_len=32, batch_size=2)


def make_state(cfg=CFG, seed=0):
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    cbs = model.init_cb_states(jax.random.PRNGKey(seed + 1), cfg)
    carry = model.init_carry(cfg, cfg.batch_size)
    opt = steps.init_opt_state(params)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 2),
                                (cfg.batch_size, cfg.window_len + 1), 0,
                                cfg.vocab_size)
    return params, opt, cbs, carry, tokens


class TestAdamW:
    def test_first_step_is_signed_lr(self):
        """With bias correction, step 1 moves ~lr * sign(grad)."""
        p = {"w": jnp.asarray([1.0, -1.0])}
        g = {"w": jnp.asarray([0.5, -0.25])}
        opt = steps.init_opt_state(p)
        cfg = CFG.replace(grad_clip=1e9)
        p2, _, _ = steps.adamw_update(p, g, opt, 0.1, cfg)
        np.testing.assert_allclose(
            np.asarray(p2["w"]), [1.0 - 0.1, -1.0 + 0.1], rtol=1e-4)

    def test_clip_bounds_update(self):
        p = {"w": jnp.zeros((4,))}
        g = {"w": jnp.full((4,), 1e6)}
        _, _, gnorm = steps.adamw_update(p, g, steps.init_opt_state(p), 0.1,
                                         CFG)
        assert float(gnorm) > 1e6  # reported norm is pre-clip

    def test_weight_decay_skips_1d(self):
        cfg = CFG.replace(weight_decay=0.5, grad_clip=1e9)
        p = {"gain": jnp.ones((4,)), "w": jnp.ones((4, 4))}
        g = {"gain": jnp.zeros((4,)), "w": jnp.zeros((4, 4))}
        p2, _, _ = steps.adamw_update(p, g, steps.init_opt_state(p), 0.1, cfg)
        np.testing.assert_allclose(np.asarray(p2["gain"]), np.ones(4))
        assert float(p2["w"][0, 0]) < 1.0

    def test_step_counter_increments(self):
        p = {"w": jnp.ones((2,))}
        g = {"w": jnp.ones((2,))}
        opt = steps.init_opt_state(p)
        _, opt1, _ = steps.adamw_update(p, g, opt, 0.1, CFG)
        _, opt2, _ = steps.adamw_update(p, g, opt1, 0.1, CFG)
        assert float(opt2["step"]) == 2.0

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert abs(float(steps.global_norm(t)) - 5.0) < 1e-6


class TestTrainStep:
    def test_loss_decreases_over_steps(self):
        params, opt, cbs, carry, tokens = make_state()
        losses = []
        for i in range(8):
            params, opt, cbs, carry, m = steps.train_step(
                params, opt, cbs, carry, tokens, jnp.float32(3e-3),
                jnp.int32(i), CFG)
            losses.append(float(m[0]))
        assert losses[-1] < losses[0], losses

    def test_metrics_layout(self):
        params, opt, cbs, carry, tokens = make_state()
        *_, m = steps.train_step(params, opt, cbs, carry, tokens,
                                 jnp.float32(1e-3), jnp.int32(0), CFG)
        assert m.shape == (6,)
        loss, ce, commit, gnorm, perp, lr = [float(x) for x in m]
        assert abs(loss - (ce + CFG.commit_coef * commit)) < 1e-3
        assert 1.0 <= perp <= CFG.n_code + 1e-3
        assert lr == pytest.approx(1e-3)

    def test_codebook_state_changes(self):
        params, opt, cbs, carry, tokens = make_state()
        _, _, cbs2, _, _ = steps.train_step(
            params, opt, cbs, carry, tokens, jnp.float32(1e-3), jnp.int32(0),
            CFG)
        d = float(jnp.max(jnp.abs(cbs2[0]["ema_count"] -
                                  cbs[0]["ema_count"])))
        assert d > 1e-6

    def test_deterministic_given_seed(self):
        a = make_state()
        b = make_state()
        ma = steps.train_step(*a[:4], a[4], jnp.float32(1e-3), jnp.int32(3),
                              CFG)[4]
        mb = steps.train_step(*b[:4], b[4], jnp.float32(1e-3), jnp.int32(3),
                              CFG)[4]
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))

    def test_full_attention_baseline_trains(self):
        cfg = CFG.replace(attn_type="full")
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        cbs = model.init_cb_states(jax.random.PRNGKey(1), cfg)
        carry = model.init_carry(cfg, cfg.batch_size)
        opt = steps.init_opt_state(params)
        tokens = jax.random.randint(jax.random.PRNGKey(2),
                                    (cfg.batch_size, cfg.window_len + 1), 0,
                                    cfg.vocab_size)
        l0 = None
        for i in range(6):
            params, opt, cbs, carry, m = steps.train_step(
                params, opt, cbs, carry, tokens, jnp.float32(3e-3),
                jnp.int32(i), cfg)
            l0 = l0 or float(m[0])
        assert float(m[0]) < l0


class TestEvalStep:
    def test_sums_and_counts(self):
        params, _, cbs, carry, tokens = make_state()
        _, m = steps.eval_step(params, cbs, carry, tokens, CFG)
        ce_sum, n = float(m[0]), float(m[1])
        assert n == CFG.batch_size * CFG.window_len
        assert 0 < ce_sum / n < 10

    def test_eval_does_not_need_dropout_rng(self):
        cfg = CFG.replace(dropout_rate=0.5)
        params, _, cbs, carry, tokens = make_state(cfg)
        m1 = steps.eval_step(params, cbs, carry, tokens, cfg)[1]
        m2 = steps.eval_step(params, cbs, carry, tokens, cfg)[1]
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


class TestTvqStore:
    def test_roundtrip(self, tmp_path):
        p = str(tmp_path / "x.tvq")
        tensors = [("a", np.arange(6, dtype=np.float32).reshape(2, 3)),
                   ("b/c", np.asarray([-1, 5], dtype=np.int32)),
                   ("s", np.float32(2.5))]
        tvq.write(p, tensors)
        back = tvq.read(p)
        assert [n for n, _ in back] == ["a", "b/c", "s"]
        np.testing.assert_array_equal(back[0][1], tensors[0][1])
        np.testing.assert_array_equal(back[1][1], tensors[1][1])
        assert back[2][1].shape == ()

    def test_scalar_shape_preserved(self, tmp_path):
        p = str(tmp_path / "s.tvq")
        tvq.write(p, [("lr", np.float32(1e-3))])
        assert tvq.read(p)[0][1].shape == ()

    def test_f64_downcast(self, tmp_path):
        p = str(tmp_path / "d.tvq")
        tvq.write(p, [("x", np.asarray([1.5], dtype=np.float64))])
        assert tvq.read(p)[0][1].dtype == np.float32
